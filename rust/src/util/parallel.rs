//! Shared fork-join parallelism for the whole pipeline (std::thread only).
//!
//! Every parallel stage in the crate — the FWHT column transform, gram
//! block production, the sharded sketch pass, K-means restarts and the
//! chunked assignment step — funnels through the two primitives here
//! instead of ad-hoc `std::thread::spawn` calls. Both are *scoped*
//! fork-joins: no worker outlives the call, no channels or locks leak,
//! and a panicking worker propagates to the caller.
//!
//! # Determinism contract
//!
//! Callers must arrange their work so the result is a pure function of
//! the inputs, independent of scheduling: disjoint output slices per
//! task, per-entry arithmetic whose accumulation order does not depend
//! on the worker count, and any reduction over task results performed in
//! task-index order ([`map_indexed`] returns results in index order for
//! exactly this reason). Under that discipline `threads = 1` and
//! `threads = N` produce bit-identical results — the contract
//! `rust/tests/parallel_determinism.rs` enforces end to end.

use std::sync::Mutex;

/// Resolve a user-facing thread-count setting: `0` means "auto-detect",
/// i.e. use [`available_threads`]; any other value is taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Hardware parallelism via `std::thread::available_parallelism`,
/// falling back to 1 when the platform cannot report it.
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f` over every task, fanning out across at most `threads` scoped
/// workers that drain a shared queue (so uneven task costs still
/// balance). With `threads <= 1` or a single task this degenerates to a
/// plain in-order loop with zero spawn overhead.
///
/// Tasks typically carry disjoint `&mut` chunks of an output buffer
/// (`slice::chunks_mut` + `enumerate`), which is what makes the
/// scheduling-independence contract above easy to uphold.
pub fn for_each_task<T: Send>(tasks: Vec<T>, threads: usize, f: impl Fn(T) + Sync) {
    let workers = threads.min(tasks.len()).max(1);
    if workers <= 1 {
        for t in tasks {
            f(t);
        }
        return;
    }
    let queue = Mutex::new(tasks.into_iter());
    let queue = &queue;
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                // the guard is a temporary of the `let` statement, so the
                // lock is released before the (expensive) task body runs
                let next = queue.lock().expect("parallel queue poisoned").next();
                let Some(task) = next else { break };
                f(task);
            });
        }
    });
}

/// Fork-join over a row-major buffer: split `data` (whose rows are
/// `row_width` elements wide) into one contiguous row range per worker
/// and call `f(first_row_index, rows)` on each. This is the shared
/// shape of every row-parallel stage (gram blocks, full-kernel rows,
/// the Nyström projection), so the offset arithmetic — and any future
/// fix to it — lives in exactly one place.
pub fn for_each_row_chunk<T: Send>(
    data: &mut [T],
    row_width: usize,
    threads: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(row_width > 0, "row width must be positive");
    assert_eq!(data.len() % row_width, 0, "buffer must be a whole number of rows");
    let nrows = data.len() / row_width;
    if nrows == 0 {
        return;
    }
    let workers = threads.min(nrows).max(1);
    let rows_per = nrows.div_ceil(workers);
    let tasks: Vec<(usize, &mut [T])> = data
        .chunks_mut(rows_per * row_width)
        .enumerate()
        .map(|(g, rows)| (g * rows_per, rows))
        .collect();
    for_each_task(tasks, workers, |(first_row, rows)| f(first_row, rows));
}

/// Map `f` over `0..n`, returning the results **in index order**. The
/// index range is split into at most `threads` contiguous spans, one
/// scoped worker each; with `threads <= 1` this is a plain sequential
/// map. Used for K-means restarts, where the winner must be reduced in
/// restart order to match the sequential loop exactly.
pub fn map_indexed<R: Send>(n: usize, threads: usize, f: impl Fn(usize) -> R + Sync) -> Vec<R> {
    let workers = threads.min(n).max(1);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let span = n.div_ceil(workers);
    let f = &f;
    let mut out = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .step_by(span)
            .map(|start| {
                let end = (start + span).min(n);
                scope.spawn(move || (start..end).map(f).collect::<Vec<R>>())
            })
            .collect();
        for h in handles {
            out.extend(h.join().expect("parallel worker panicked"));
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_zero_means_auto() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(0), available_threads());
    }

    #[test]
    fn for_each_task_runs_every_task_once() {
        for threads in [1usize, 2, 4, 9] {
            let hits = AtomicUsize::new(0);
            let mut out = vec![0usize; 23];
            let tasks: Vec<(usize, &mut [usize])> =
                out.chunks_mut(5).enumerate().collect();
            for_each_task(tasks, threads, |(g, chunk)| {
                hits.fetch_add(chunk.len(), Ordering::Relaxed);
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = g * 5 + i;
                }
            });
            assert_eq!(hits.load(Ordering::Relaxed), 23, "threads={threads}");
            assert_eq!(out, (0..23).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn for_each_task_handles_empty_and_single() {
        for_each_task(Vec::<usize>::new(), 4, |_| panic!("no tasks to run"));
        let hits = AtomicUsize::new(0);
        for_each_task(vec![7usize], 4, |t| {
            hits.fetch_add(t, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn map_indexed_preserves_order_for_any_thread_count() {
        let want: Vec<usize> = (0..57).map(|i| i * i).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            assert_eq!(map_indexed(57, threads, |i| i * i), want, "threads={threads}");
        }
        assert!(map_indexed(0, 4, |i| i).is_empty());
    }

    // scope auto-join surfaces a worker panic as "a scoped thread
    // panicked"; match the stable substring only
    #[test]
    #[should_panic(expected = "panicked")]
    fn worker_panic_propagates() {
        for_each_task(vec![0usize, 1, 2, 3], 2, |t| {
            if t == 2 {
                panic!("worker exploded");
            }
        });
    }
}
