//! Small shared utilities: a dependency-free JSON parser (the artifact
//! manifest and experiment configs are JSON; serde is unavailable on this
//! offline image), the scoped fork-join helpers every parallel stage
//! shares ([`parallel`]), and misc statistics helpers.

pub mod json;
pub mod parallel;

pub use json::Json;

/// Format a float with a fixed number of significant decimals for tables.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for len < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (nearest-rank) of an unsorted slice. NaN inputs are
/// tolerated: the IEEE total order is deterministic and never panics
/// (serving latency counters feed this; a stray NaN must not take down
/// the metrics path). Note the total order sorts positive NaN after
/// +inf but *negative* NaN before -inf, so a NaN in the data can
/// surface at either extreme of the rank range.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stats_degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_survives_nan_inputs() {
        // the old partial_cmp().unwrap() sort panicked here
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        // sorted [1, 2, 3, NaN]: nearest-rank 50th = index (0.5·3).round() = 2
        assert_eq!(percentile(&xs, 50.0), 3.0);
        // positive NaN sorts last under total order: only the top rank
        // sees it; a sign-flipped NaN would sort first instead
        assert!(percentile(&xs, 100.0).is_nan());
        assert_eq!(percentile(&[-f64::NAN, 1.0, 2.0], 100.0), 2.0);
        assert!(percentile(&[-f64::NAN, 1.0, 2.0], 0.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }
}
