//! Small shared utilities: a dependency-free JSON parser (the artifact
//! manifest and experiment configs are JSON; serde is unavailable on this
//! offline image), the scoped fork-join helpers every parallel stage
//! shares ([`parallel`]), and misc statistics helpers.

pub mod json;
pub mod parallel;

pub use json::Json;

/// Format a float with a fixed number of significant decimals for tables.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for len < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stats_degenerate() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }
}
