//! Minimal recursive-descent JSON parser and writer.
//!
//! Drives the artifact manifest (`artifacts/manifest.json`, written by
//! python/compile/aot.py) and experiment config files. Supports the full
//! JSON grammar except `\u` surrogate pairs beyond the BMP; numbers are
//! parsed as `f64` (ints round-trip exactly up to 2^53, far beyond any
//! shape we store).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// A number, with non-finite values downgraded to [`Json::Null`] —
    /// JSON has no inf/NaN literals, so this is the one shared rule for
    /// putting an arbitrary `f64` into a document that must stay
    /// parseable (model headers, HTTP responses, bench records).
    pub fn finite_num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Convenience: `obj.str_field("name")` with a descriptive error.
    pub fn str_field(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError { msg: format!("missing string field '{key}'"), pos: 0 })
    }

    pub fn usize_field(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| JsonError { msg: format!("missing integer field '{key}'"), pos: 0 })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap for the recursive-descent parser. The parser now also
/// reads untrusted input (HTTP request bodies, model-file headers), and
/// unbounded recursion would let `[[[[…` overflow the thread stack; our
/// real documents nest ≤ 4 levels, so 128 is generous.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than the supported maximum"));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                // the integer short form would collapse -0.0 to "0" and
                // break bit-exact f64 roundtrips; "-0" parses back to
                // -0.0, so route it through the float path
                if v.fract() == 0.0 && v.abs() < 1e15 && !(*v == 0.0 && v.is_sign_negative())
                {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let src = r#"{"name": "gram", "shape": [4096, 256], "meta": {"ok": true, "x": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.str_field("name").unwrap(), "gram");
        let shape = v.get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 4096);
        assert_eq!(v.get("meta").unwrap().get("ok").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"c\" A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A é");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "1 2", "tru"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"b":[1,2.5,"x"],"a":{"k":null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn negative_zero_roundtrips_bit_exactly() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        // the positive-zero short form is untouched
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"[
 {"name": "precond_n256_b64", "file": "precond_n256_b64.hlo.txt",
  "params": {"op": "precond", "n": 256, "b": 64},
  "inputs": [{"shape": [256, 64], "dtype": "float32"},
             {"shape": [256], "dtype": "float32"}],
  "outputs": [{"shape": [256, 64], "dtype": "float32"}]}
]"#;
        let v = Json::parse(src).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("params").unwrap().str_field("op").unwrap(), "precond");
        assert_eq!(arr[0].get("inputs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn pathological_nesting_is_an_error_not_a_stack_overflow() {
        // untrusted input (HTTP bodies, model headers) must never crash
        // the parser
        let deep_ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep_ok).is_ok());
        let too_deep = format!("{}1{}", "[".repeat(50_000), "]".repeat(50_000));
        let err = Json::parse(&too_deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        let unclosed = "[".repeat(50_000);
        assert!(Json::parse(&unclosed).is_err());
    }

    #[test]
    fn finite_num_downgrades_non_finite_to_null() {
        assert_eq!(Json::finite_num(2.5), Json::Num(2.5));
        assert_eq!(Json::finite_num(f64::INFINITY), Json::Null);
        assert_eq!(Json::finite_num(f64::NEG_INFINITY), Json::Null);
        assert_eq!(Json::finite_num(f64::NAN), Json::Null);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
