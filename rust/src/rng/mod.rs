//! Deterministic pseudo-randomness for the whole stack.
//!
//! The paper's method is randomized (Rademacher signs, uniform row
//! sampling, K-means++ seeding, 100-trial experiment protocol), so every
//! consumer in this crate draws from a seedable, splittable PRNG to make
//! experiments exactly reproducible. We implement PCG-XSH-RR-64/32
//! (O'Neill 2014) — small state, excellent statistical quality, and no
//! external crates required on this offline image.

mod pcg;

pub use pcg::Pcg64;

/// Source of uniform `u32`s; everything else is derived from this.
pub trait Rng {
    fn next_u32(&mut self) -> u32;

    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's rejection method
    /// (unbiased, at most a few retries).
    fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0) is meaningless");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second deviate would need
    /// state; we draw the pair fresh — clarity over the last nanosecond).
    fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Rademacher sign: ±1 with equal probability.
    fn rademacher(&mut self) -> f64 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

impl Rng for Pcg64 {
    fn next_u32(&mut self) -> u32 {
        self.next()
    }
}

/// `len` i.i.d. Rademacher signs (the diagonal of `D` in Alg. 1).
pub fn rademacher_vec(rng: &mut impl Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.rademacher()).collect()
}

/// `len` i.i.d. standard normals.
pub fn normal_vec(rng: &mut impl Rng, len: usize) -> Vec<f64> {
    (0..len).map(|_| rng.normal()).collect()
}

/// In-place Fisher–Yates shuffle.
pub fn shuffle<T>(rng: &mut impl Rng, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        xs.swap(i, rng.below(i + 1));
    }
}

/// `k` distinct indices drawn uniformly without replacement from `0..n`
/// (the sub-sampling matrix `R` of Alg. 1 and the Nyström column draw).
/// Uses a partial Fisher–Yates over an index table: O(n) memory, O(n)
/// time, exact uniformity over k-subsets.
pub fn sample_without_replacement(rng: &mut impl Rng, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "cannot sample {k} of {n} without replacement");
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.below(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::seed(7);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| {
                let x = rng.next_f64();
                assert!((0.0..1.0).contains(&x));
                x
            })
            .sum::<f64>()
            / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut rng = Pcg64::seed(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut rng = Pcg64::seed(5);
        let signs = rademacher_vec(&mut rng, 10_000);
        let plus = signs.iter().filter(|&&s| s == 1.0).count();
        assert!(signs.iter().all(|&s| s == 1.0 || s == -1.0));
        assert!((plus as f64 - 5_000.0).abs() < 300.0);
    }

    #[test]
    fn sample_without_replacement_is_distinct_and_in_range() {
        let mut rng = Pcg64::seed(9);
        for _ in 0..50 {
            let s = sample_without_replacement(&mut rng, 100, 17);
            assert_eq!(s.len(), 17);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 17, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn sample_without_replacement_full_is_permutation() {
        let mut rng = Pcg64::seed(13);
        let mut s = sample_without_replacement(&mut rng, 20, 20);
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn sample_marginals_are_uniform() {
        // each index should appear in a k-subset with probability k/n
        let mut rng = Pcg64::seed(17);
        let (n, k, trials) = (30, 6, 20_000);
        let mut hits = vec![0usize; n];
        for _ in 0..trials {
            for i in sample_without_replacement(&mut rng, n, k) {
                hits[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64; // 4000
        for &h in &hits {
            assert!((h as f64 - expect).abs() < 350.0, "hits={hits:?}");
        }
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut rng = Pcg64::seed(23);
        let mut xs: Vec<u32> = (0..57).collect();
        shuffle(&mut rng, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
        assert_ne!(xs, (0..57).collect::<Vec<_>>());
    }
}
