//! PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
//! Reference: M.E. O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).

const MULT: u64 = 6364136223846793005;

/// The crate-wide PRNG. Seedable and cheaply splittable into independent
/// streams (distinct odd increments select distinct PCG sequences).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

impl Pcg64 {
    /// Seed with the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream id (any value; forced odd internally).
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next();
        rng.state = rng.state.wrapping_add(seed);
        rng.next();
        rng
    }

    /// Derive an independent child stream; used to give every trial /
    /// pipeline worker its own sequence while staying reproducible.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64_internal();
        Pcg64::seed_stream(seed ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Advance the LCG and emit 32 output bits (XSH-RR permutation).
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    fn next_u64_internal(&mut self) -> u64 {
        ((self.next() as u64) << 32) | self.next() as u64
    }

    /// The raw `(state, increment)` pair — everything this generator
    /// is. Exists for checkpointing: a stream's exact position survives
    /// a save/restore round-trip through [`from_parts`](Self::from_parts)
    /// even when the number of values consumed so far is unknowable
    /// (rejection sampling in [`below`](crate::rng::Rng::below) draws a
    /// variable count).
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`state_parts`](Self::state_parts)
    /// output. The restored generator emits exactly the sequence the
    /// saved one would have emitted next.
    pub fn from_parts(state: u64, inc: u64) -> Self {
        Pcg64 { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_are_independent() {
        let mut root = Pcg64::seed(99);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..256).filter(|_| a.next() == b.next()).count();
        assert!(same < 8, "split streams correlate: {same}/256 equal");
    }

    #[test]
    fn state_parts_roundtrip_resumes_the_exact_sequence() {
        let mut rng = Pcg64::seed_stream(7, 0x57cea);
        for _ in 0..17 {
            rng.next();
        }
        let (state, inc) = rng.state_parts();
        let mut restored = Pcg64::from_parts(state, inc);
        for _ in 0..64 {
            assert_eq!(restored.next(), rng.next());
        }
    }

    #[test]
    fn full_32bit_range_is_hit() {
        let mut rng = Pcg64::seed(1);
        let (mut lo, mut hi) = (u32::MAX, 0u32);
        for _ in 0..100_000 {
            let x = rng.next();
            lo = lo.min(x);
            hi = hi.max(x);
        }
        assert!(lo < u32::MAX / 50);
        assert!(hi > u32::MAX - u32::MAX / 50);
    }
}
