//! Hot-swap contract of the streaming subsystem: while `StreamClusterer`
//! keeps publishing refreshed generations into a live `ModelRegistry`,
//! every concurrent HTTP client response is bit-identical to **exactly
//! one** published generation — never a blend of two, never a torn
//! model. Generations observed over the wire are monotone
//! non-decreasing, and the counters (`generation`, `queue_highwater`)
//! surface in `GET /models/{name}` and `/healthz`.
//!
//! The harness exploits that the main thread is the only publisher: the
//! expected response body for generation g is snapshotted immediately
//! after publishing g (no publish can intervene), so the set of
//! snapshots is the exact universe of legal responses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rkc::bench_harness::MiniHttpClient;
use rkc::data;
use rkc::linalg::Mat;
use rkc::rng::Pcg64;
use rkc::serve::{serve_http_registry, HttpOpts, ModelRegistry, ServeOpts};
use rkc::stream::StreamClusterer;
use rkc::util::Json;

fn points_json(x: &Mat) -> String {
    let pts: Vec<Json> = (0..x.cols())
        .map(|j| Json::Arr((0..x.rows()).map(|i| Json::Num(x[(i, j)])).collect()))
        .collect();
    Json::Obj(BTreeMap::from([("points".to_string(), Json::Arr(pts))])).to_string()
}

fn column_slice(x: &Mat, lo: usize, m: usize) -> Mat {
    Mat::from_fn(x.rows(), m, |i, j| x[(i, lo + j)])
}

/// One `Connection: close` request, so snapshots never interleave with
/// the keep-alive observers' connections.
fn fetch(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
    let mut c = MiniHttpClient::connect(addr);
    let (status, resp) = c.request_with(method, path, body, true);
    assert_eq!(status, 200, "{method} {path}: {resp}");
    resp
}

#[test]
fn concurrent_clients_see_exactly_one_published_generation_per_response() {
    let ds = data::cross_lines(&mut Pcg64::seed(101), 240);
    let chunk = 60;
    let mut sc = StreamClusterer::new(2)
        .oversample(8)
        .seed(33)
        .threads(0)
        .capacity(ds.x.cols());

    let registry = Arc::new(ModelRegistry::new(ServeOpts::default()));
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts { workers: 6, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr();
    let query = data::cross_lines(&mut Pcg64::seed(102), 7).x;
    let body = points_json(&query);

    // generation 1 is live before any traffic starts
    sc.ingest(&column_slice(&ds.x, 0, chunk)).unwrap();
    assert_eq!(sc.publish(&registry, "stream").unwrap(), 1);
    let mut expected = vec![fetch(addr, "POST", "/models/stream/embed", &body)];

    let stop = AtomicBool::new(false);
    let (observed, last_polled) = std::thread::scope(|s| {
        let observers: Vec<_> = (0..3)
            .map(|_| {
                let (stop, body) = (&stop, &body);
                s.spawn(move || {
                    let mut c = MiniHttpClient::connect(addr);
                    let mut seen = Vec::new();
                    while !stop.load(Ordering::Relaxed) {
                        let (status, resp) =
                            c.request("POST", "/models/stream/embed", body);
                        assert_eq!(status, 200, "{resp}");
                        seen.push(resp);
                    }
                    seen
                })
            })
            .collect();
        // a fourth client watches the generation counter for monotonicity
        let poller = {
            let stop = &stop;
            s.spawn(move || {
                let mut c = MiniHttpClient::connect(addr);
                let mut last = 0.0_f64;
                while !stop.load(Ordering::Relaxed) {
                    let (status, resp) = c.request("GET", "/models/stream", "");
                    assert_eq!(status, 200, "{resp}");
                    let info = Json::parse(&resp).unwrap();
                    let g = info.get("generation").unwrap().as_f64().unwrap();
                    assert!(
                        g >= last,
                        "generation went backwards over the wire: {last} -> {g}"
                    );
                    assert!(
                        info.get("queue_highwater").unwrap().as_f64().is_some(),
                        "{resp}"
                    );
                    last = g;
                }
                last
            })
        };

        // three more generations hot-swap in under live traffic; each
        // expected body is snapshotted while its generation is current
        for round in 1..4 {
            sc.ingest(&column_slice(&ds.x, round * chunk, chunk)).unwrap();
            let g = sc.publish(&registry, "stream").unwrap();
            assert_eq!(g, round as u64 + 1);
            expected.push(fetch(addr, "POST", "/models/stream/embed", &body));
        }
        stop.store(true, Ordering::Relaxed);

        let mut observed = Vec::new();
        for o in observers {
            observed.extend(o.join().unwrap());
        }
        (observed, poller.join().unwrap())
    });
    assert_eq!(expected.len(), 4);

    // the generations are genuinely different models (different n_train
    // ⇒ different embeddings), so "matches exactly one" is meaningful
    for a in 0..expected.len() {
        for b in a + 1..expected.len() {
            assert_ne!(
                expected[a], expected[b],
                "generations {a} and {b} must answer differently"
            );
        }
    }
    assert!(!observed.is_empty(), "observers made no requests");
    for resp in &observed {
        assert!(
            expected.contains(resp),
            "a concurrent response matches NO published generation (torn swap?): {resp}"
        );
    }
    assert!(last_polled <= 4.0, "polled generation beyond the publish count");

    // final registry + health state: generation == publish count
    let info = Json::parse(&fetch(addr, "GET", "/models/stream", "")).unwrap();
    assert_eq!(info.get("generation").unwrap().as_f64().unwrap(), 4.0);
    assert_eq!(info.get("n_train").unwrap().as_f64().unwrap(), 240.0);
    let health = Json::parse(&fetch(addr, "GET", "/healthz", "")).unwrap();
    assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(health.get("generation").unwrap().as_f64().unwrap(), 4.0);

    http.shutdown();
}

#[test]
fn republish_after_unload_does_not_reuse_generations() {
    // the per-name generation counter survives unload, so a client that
    // cached "generation 2" can never see a *different* model under the
    // same (name, generation) pair later
    let ds = data::cross_lines(&mut Pcg64::seed(103), 120);
    let mut sc = StreamClusterer::new(2).oversample(8).seed(9).capacity(120);
    let registry = Arc::new(ModelRegistry::new(ServeOpts::default()));

    sc.ingest(&column_slice(&ds.x, 0, 60)).unwrap();
    assert_eq!(sc.publish(&registry, "stream").unwrap(), 1);
    assert_eq!(sc.publish(&registry, "stream").unwrap(), 2);
    assert!(registry.unload("stream"));
    sc.ingest(&column_slice(&ds.x, 60, 60)).unwrap();
    assert_eq!(
        sc.publish(&registry, "stream").unwrap(),
        3,
        "generation counter must survive unload"
    );
    let info = registry.info("stream").unwrap();
    assert_eq!(info.generation, 3);
}
