//! Integration tests over the PJRT runtime + compiled artifacts.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`
//! *and* a build with the `xla` feature; every test skips silently when
//! either is missing (CI runs without the compiled artifact set). The
//! tests exercise the test-scale artifacts (n=256, b=64) plus one
//! production-shape smoke test, verifying the XLA path agrees with the
//! native rust implementations to f32 tolerance.

use rkc::clustering::KmeansOpts;
use rkc::config::{Backend, ExperimentConfig, Method};
use rkc::coordinator::{run_experiment, run_trials, XlaBlockSource};
use rkc::data;
use rkc::kernels::{BlockSource, Kernel, NativeBlockSource};
use rkc::linalg::Mat;
use rkc::rng::{Pcg64, Rng};
use rkc::runtime::{literal_to_mat, mat_to_literal, vec_to_literal, ArtifactRegistry};

// PJRT handles are !Send/!Sync (Rc-backed), so each test owns its own
// registry; artifacts compile lazily and only the test-scale ones are
// touched here, keeping this cheap. Returns None (=> skip) when the
// artifact set or the xla feature is unavailable.
fn registry() -> Option<ArtifactRegistry> {
    let reg = ArtifactRegistry::open("artifacts").ok()?;
    // a registry that cannot compile anything (no `xla` feature) is as
    // good as absent for these tests; probe with a known test-scale
    // artifact so the availability check never compiles a production one
    let probe = if reg.info("precond_n256_b64").is_some() {
        "precond_n256_b64".to_string()
    } else {
        reg.names().into_iter().next()?
    };
    reg.get(&probe).ok()?;
    Some(reg)
}

fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

#[test]
fn manifest_lists_all_artifact_families() {
    let Some(reg) = registry() else { return };
    let names = reg.names();
    for needle in ["gram_poly2h_p4_n256_b64", "precond_n256_b64", "kmeans_step_r2_k3_n256"] {
        assert!(names.iter().any(|n| n == needle), "missing {needle} in {names:?}");
    }
}

#[test]
fn gram_artifact_matches_native_gram() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg64::seed(1);
    let x = random_mat(&mut rng, 4, 200); // pads to 256
    let kern = Kernel::paper_poly2();
    let mut xla_src = XlaBlockSource::new(&reg, x.clone(), kern, 256).unwrap();
    let mut nat_src = NativeBlockSource::new(x, kern, 256);
    let cols: Vec<usize> = vec![0, 3, 77, 199, 42];
    let a = xla_src.block(&cols);
    let b = nat_src.block(&cols);
    assert_eq!((a.rows(), a.cols()), (256, 5));
    let diff = a.sub(&b).max_abs();
    assert!(diff < 1e-3, "gram artifact vs native differ by {diff}");
}

#[test]
fn precond_artifact_matches_native_srht() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg64::seed(2);
    let exe = reg.get("precond_n256_b64").unwrap();
    let kb = random_mat(&mut rng, 256, 64);
    let d: Vec<f64> = (0..256).map(|_| rng.rademacher()).collect();
    let outs = exe
        .run(&[mat_to_literal(&kb).unwrap(), vec_to_literal(&d).unwrap()])
        .unwrap();
    let got = literal_to_mat(&outs[0], 256, 64).unwrap();
    // native reference: scale rows by d, FWHT each column
    let mut cols: Vec<Vec<f64>> = (0..64)
        .map(|j| (0..256).map(|i| kb[(i, j)] * d[i]).collect())
        .collect();
    rkc::sketch::fwht_columns(&mut cols, 1);
    let want = Mat::from_fn(256, 64, |i, j| cols[j][i]);
    let scale = want.max_abs().max(1.0);
    let diff = got.sub(&want).max_abs();
    assert!(diff < 1e-3 * scale, "precond artifact differs by {diff} (scale {scale})");
}

#[test]
fn fused_sketch_pipeline_matches_native_pipeline() {
    let Some(reg) = registry() else { return };
    // run the full one-pass method on both backends with the same seed:
    // identical SRHT draw => embeddings must reconstruct the same K̂
    let mut cfg = ExperimentConfig::default();
    cfg.dataset = "blobs".into();
    cfg.n = 200;
    cfg.p = 4;
    cfg.k = 3;
    cfg.method = Method::OnePass;
    cfg.rank = 2;
    cfg.oversample = 6;
    cfg.batch = 64;
    cfg.kmeans_restarts = 4;
    cfg.kmeans_iters = 15;
    let ds = rkc::coordinator::build_dataset(&cfg).unwrap();

    cfg.backend = Backend::Native;
    let nat = run_experiment(&cfg, &ds, None, 99).unwrap();
    cfg.backend = Backend::Xla;
    let xla = run_experiment(&cfg, &ds, Some(&reg), 99).unwrap();

    assert!(
        (nat.approx_error - xla.approx_error).abs() < 5e-3,
        "native err {} vs xla err {}",
        nat.approx_error,
        xla.approx_error
    );
    assert!((nat.accuracy - xla.accuracy).abs() < 0.05,
        "native acc {} vs xla acc {}", nat.accuracy, xla.accuracy);
}

#[test]
fn xla_kmeans_agrees_with_native_kmeans() {
    let Some(reg) = registry() else { return };
    let mut rng = Pcg64::seed(5);
    // three separated blobs in r=2
    let mut ds = data::gaussian_blobs(&mut rng, 180, 2, 3, 0.4);
    data::normalize_columns(&mut ds.x); // keep coordinates tame for f32
    let opts = KmeansOpts { k: 3, restarts: 5, max_iters: 20, tol: 1e-9 };
    let mut rng_a = Pcg64::seed(7);
    let mut rng_b = Pcg64::seed(7);
    let nat = rkc::clustering::kmeans(&ds.x, &opts, &mut rng_a);
    let xla = rkc::coordinator::xla_kmeans(&reg, &ds.x, &opts, &mut rng_b).unwrap();
    // same seeding => same clustering (up to f32 noise in distances)
    let agree = nat
        .labels
        .iter()
        .zip(&xla.labels)
        .filter(|(a, b)| a == b)
        .count();
    assert!(agree as f64 / 180.0 > 0.98, "only {agree}/180 labels agree");
    assert!((nat.objective - xla.objective).abs() < 1e-3 * nat.objective.max(1.0));
}

#[test]
fn xla_trials_on_cross_lines_beat_plain_kmeans() {
    let Some(reg) = registry() else { return };
    // end-to-end XLA backend on a (shrunk) Table-1 workload
    let mut cfg = ExperimentConfig::table1();
    cfg.n = 240;
    cfg.trials = 2;
    cfg.kmeans_restarts = 5;
    cfg.backend = Backend::Xla;
    let ds = rkc::coordinator::build_dataset(&cfg).unwrap();
    let ours = run_trials(&cfg, &ds, Some(&reg)).unwrap();
    assert!(ours.accuracy_mean > 0.9, "xla one-pass accuracy {}", ours.accuracy_mean);
}

#[test]
fn srht_masked_padding_keeps_rbf_consistent_across_backends() {
    let Some(reg) = registry() else { return };
    // RBF padded rows are nonzero in the raw artifact output; the d-mask
    // must make both backends agree anyway
    let mut rng = Pcg64::seed(11);
    let x = random_mat(&mut rng, 2, 100); // pads 100 -> 128
    let kern = Kernel::Rbf { gamma: 2.0 };
    let n_pad = 256;
    let mut xla_src = match XlaBlockSource::new(&reg, x.clone(), kern, n_pad) {
        Ok(s) => s,
        Err(_) => return, // no rbf p=2 n=256 artifact in the set — skip
    };
    let blk = xla_src.block(&[0, 1]);
    for i in 100..n_pad {
        assert_eq!(blk[(i, 0)], 0.0, "padded row {i} must be zeroed");
    }
}
