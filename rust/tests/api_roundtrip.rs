//! Integration tests for the library-first `rkc::api` surface:
//! builder validation, fit → predict round-trips, out-of-sample
//! embedding consistency, and `FromStr`/`Display` round-trips.

use rkc::api::KernelClusterer;
use rkc::clustering::accuracy;
use rkc::config::{Backend, Method, DEFAULT_NYSTROM_M};
use rkc::data;
use rkc::error::RkcError;
use rkc::kernels::Kernel;
use rkc::rng::Pcg64;

#[test]
fn builder_validation_is_typed() {
    let x = data::cross_lines(&mut Pcg64::seed(1), 60).x;
    for bad in [
        KernelClusterer::new(2).rank(0),              // rank 0
        KernelClusterer::new(2).rank(4).oversample(2), // oversampling < rank
        KernelClusterer::new(61),                     // k > n
        KernelClusterer::new(0),                      // k = 0
        KernelClusterer::new(2).rank(70),             // rank > n
        KernelClusterer::new(2).batch(0),             // degenerate batch
        KernelClusterer::new(2).method(Method::Nystrom { m: 1 }).rank(2), // m < r
        KernelClusterer::new(2).method(Method::Nystrom { m: 100 }),       // m > n
    ] {
        let err = bad.fit(&x).unwrap_err();
        assert!(matches!(err, RkcError::InvalidConfig(_)), "wrong variant: {err}");
    }
}

#[test]
fn fit_predict_roundtrip_on_two_rings() {
    let train = data::two_rings(&mut Pcg64::seed(3), 800);
    let model = KernelClusterer::new(2).rank(2).oversample(10).seed(5).fit(&train.x).unwrap();
    let acc_in = accuracy(model.labels(), &train.labels, 2);

    let held_out = data::two_rings(&mut Pcg64::seed(4), 400);
    let predicted = model.predict(&held_out.x).unwrap();
    let acc_out = accuracy(&predicted, &held_out.labels, 2);

    assert!(acc_in > 0.6, "in-sample accuracy degenerate: {acc_in}");
    assert!(
        (acc_in - acc_out).abs() < 0.1,
        "held-out accuracy {acc_out} drifts from in-sample {acc_in}"
    );
}

#[test]
fn fit_predict_roundtrip_on_cross_lines() {
    // rank 3 covers the R² quadratic kernel's spectrum: the out-of-sample
    // extension is near-exact and held-out accuracy matches in-sample
    let train = data::cross_lines(&mut Pcg64::seed(6), 600);
    let model = KernelClusterer::new(2).rank(3).oversample(10).seed(7).fit(&train.x).unwrap();
    let acc_in = accuracy(model.labels(), &train.labels, 2);
    assert!(acc_in > 0.9, "in-sample accuracy {acc_in}");

    let held_out = data::cross_lines(&mut Pcg64::seed(8), 300);
    let predicted = model.predict(&held_out.x).unwrap();
    let acc_out = accuracy(&predicted, &held_out.labels, 2);
    assert!(acc_out > 0.85, "held-out accuracy {acc_out}");
    assert!((acc_in - acc_out).abs() < 0.1, "in {acc_in} vs out {acc_out}");

    // re-predicting the training set agrees with the fit labels
    let repredicted = model.predict(&train.x).unwrap();
    let agree = repredicted.iter().zip(model.labels()).filter(|(a, b)| a == b).count();
    assert!(agree as f64 / 600.0 > 0.95, "only {agree}/600 training points agree");
}

#[test]
fn out_of_sample_embed_matches_in_sample_embedding() {
    // with the spectrum fully covered (rank 3 on an R² quadratic kernel)
    // the column-map extension reproduces the in-sample embedding
    let train = data::cross_lines(&mut Pcg64::seed(9), 128);
    let model = KernelClusterer::new(2).rank(3).oversample(10).seed(11).fit(&train.x).unwrap();
    let emb = model.embedding().expect("one-pass builds an embedding");
    let re_embedded = model.embed(&train.x).unwrap();
    assert_eq!((re_embedded.rows(), re_embedded.cols()), (3, 128));
    let scale = emb.y.max_abs().max(1e-12);
    let diff = re_embedded.sub(&emb.y).max_abs();
    // the extension error is the recovery error amplified by 1/sqrt(λ_i),
    // so allow a generous (but still tight in absolute terms) margin
    assert!(
        diff < 1e-3 * scale.max(1.0),
        "extension differs from in-sample embedding by {diff} (scale {scale})"
    );
}

#[test]
fn every_embedding_method_roundtrips_through_the_builder() {
    let train = data::cross_lines(&mut Pcg64::seed(12), 160);
    for method in [
        Method::OnePass,
        Method::GaussianOnePass,
        Method::Nystrom { m: 60 },
        Method::Exact,
    ] {
        let model = KernelClusterer::new(2)
            .method(method)
            .rank(2)
            .oversample(8)
            .seed(13)
            .fit(&train.x)
            .unwrap();
        let acc = accuracy(model.labels(), &train.labels, 2);
        assert!(acc > 0.9, "{method}: accuracy {acc}");
        let pred = model.predict(&train.x).unwrap();
        assert_eq!(pred.len(), 160, "{method}");
        let err = model.approx_error().unwrap();
        assert!(err.is_finite() && err < 1.0, "{method}: approx error {err}");
    }
}

#[test]
fn dimension_mismatch_is_a_typed_error() {
    let train = data::cross_lines(&mut Pcg64::seed(14), 80);
    let model = KernelClusterer::new(2).oversample(8).fit(&train.x).unwrap();
    let wrong_p = data::gaussian_blobs(&mut Pcg64::seed(15), 10, 5, 2, 0.3);
    assert!(model.predict(&wrong_p.x).is_err());
    assert!(model.embed(&wrong_p.x).is_err());
}

#[test]
fn method_fromstr_display_roundtrip_and_aliases() {
    for m in [
        Method::OnePass,
        Method::GaussianOnePass,
        Method::Nystrom { m: 20 },
        Method::Nystrom { m: DEFAULT_NYSTROM_M },
        Method::Exact,
        Method::FullKernel,
        Method::PlainKmeans,
    ] {
        assert_eq!(m.to_string().parse::<Method>().unwrap(), m, "{m}");
    }
    // bare `nystrom` gets the documented default m
    assert_eq!("nystrom".parse::<Method>().unwrap(), Method::Nystrom { m: DEFAULT_NYSTROM_M });
    // historical aliases still parse
    assert_eq!("ours".parse::<Method>().unwrap(), Method::OnePass);
    assert_eq!("plain".parse::<Method>().unwrap(), Method::PlainKmeans);
    assert!(matches!("warp".parse::<Method>(), Err(RkcError::Parse { .. })));
}

#[test]
fn backend_and_kernel_fromstr_display_roundtrip() {
    for b in [Backend::Native, Backend::Xla] {
        assert_eq!(b.to_string().parse::<Backend>().unwrap(), b);
    }
    for k in [
        Kernel::paper_poly2(),
        Kernel::Poly { gamma: 0.5, degree: 4 },
        Kernel::Rbf { gamma: 1.25 },
        Kernel::Linear,
    ] {
        assert_eq!(k.to_string().parse::<Kernel>().unwrap(), k, "{k}");
    }
}
