//! The parallel subsystem's determinism contract, end to end: for a
//! fixed seed, `fit` must produce **bit-identical** labels and objective
//! for every thread count — `threads(1)`, `threads(4)`, and the
//! auto-detected `threads(0)` — on every native method. See
//! ARCHITECTURE.md §Determinism for why this holds by construction.

use rkc::api::KernelClusterer;
use rkc::config::Method;
use rkc::data;
use rkc::rng::Pcg64;

/// Fit cross-lines with the given thread count and return the outputs
/// that must not depend on it.
fn fit_with(method: Method, n: usize, threads: usize, seed: u64) -> (Vec<usize>, f64) {
    let ds = data::cross_lines(&mut Pcg64::seed(11), n);
    let model = KernelClusterer::new(2)
        .method(method)
        .rank(2)
        .oversample(8)
        .batch(32)
        .seed(seed)
        .threads(threads)
        .fit(&ds.x)
        .expect("fit");
    (model.labels().to_vec(), model.metrics().objective)
}

fn assert_thread_invariant_at(method: Method, n: usize) {
    for seed in [7u64, 2016] {
        let (base_labels, base_obj) = fit_with(method, n, 1, seed);
        for threads in [2usize, 4, 0] {
            let (labels, obj) = fit_with(method, n, threads, seed);
            assert_eq!(
                base_labels, labels,
                "{method}: labels diverged at threads={threads} seed={seed}"
            );
            assert_eq!(
                base_obj.to_bits(),
                obj.to_bits(),
                "{method}: objective diverged at threads={threads} seed={seed} \
                 ({base_obj} vs {obj})"
            );
        }
    }
}

#[test]
fn one_pass_is_thread_count_invariant() {
    assert_thread_invariant_at(Method::OnePass, 300);
}

#[test]
fn nystrom_is_thread_count_invariant() {
    assert_thread_invariant_at(Method::Nystrom { m: 40 }, 300);
}

#[test]
fn exact_is_thread_count_invariant() {
    assert_thread_invariant_at(Method::Exact, 300);
}

#[test]
fn gaussian_one_pass_is_thread_count_invariant() {
    assert_thread_invariant_at(Method::GaussianOnePass, 300);
}

#[test]
fn plain_kmeans_is_thread_count_invariant() {
    assert_thread_invariant_at(Method::PlainKmeans, 300);
}

#[test]
fn full_kernel_is_thread_count_invariant() {
    // kernel K-means on the (threaded) materialized kernel; smaller n —
    // the O(n²) baseline is the expensive one
    assert_thread_invariant_at(Method::FullKernel, 120);
}

/// The streamed entry point honors the same contract: embedder-level
/// threading (FWHT, Nyström projection) must not change the fit.
#[test]
fn fit_stream_is_thread_count_invariant() {
    use rkc::kernels::{Kernel, NativeBlockSource};
    let ds = data::cross_lines(&mut Pcg64::seed(13), 200);
    let run = |threads: usize| {
        let src = NativeBlockSource::pow2(ds.x.clone(), Kernel::paper_poly2());
        let model = KernelClusterer::new(2)
            .oversample(8)
            .seed(5)
            .threads(threads)
            .fit_stream(src)
            .expect("fit_stream");
        (model.labels().to_vec(), model.metrics().objective)
    };
    let (base_labels, base_obj) = run(1);
    for threads in [3usize, 0] {
        let (labels, obj) = run(threads);
        assert_eq!(base_labels, labels, "threads={threads}");
        assert_eq!(base_obj.to_bits(), obj.to_bits(), "threads={threads}");
    }
}
