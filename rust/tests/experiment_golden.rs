//! Golden determinism for the experiment harness: the committed
//! `plans/smoke.plan` (16 trials, `timings false`) must produce
//! **byte-identical** JSONL on every run and at every runner thread
//! count. Trial seeds are pure functions of the trial coordinates and
//! runner parallelism never enters a trial's computation, so the
//! output is pinned by the plan text alone — the same contract the CI
//! smoke job re-checks with `cmp` on the real binary's output.

use rkc::experiment::{expand, plan_hash, run_plan_text, Plan};

const SMOKE: &str = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/plans/smoke.plan"));

#[test]
fn smoke_plan_jsonl_is_byte_identical_across_reruns_and_threads() {
    let first = run_plan_text(SMOKE, 1).expect("run smoke plan");
    let again = run_plan_text(SMOKE, 1).expect("rerun smoke plan");
    let parallel = run_plan_text(SMOKE, 4).expect("run smoke plan threaded");
    assert_eq!(first.jsonl, again.jsonl, "rerun diverged");
    assert_eq!(first.jsonl, parallel.jsonl, "threads=4 diverged from threads=1");
}

#[test]
fn smoke_plan_report_shape_matches_the_plan() {
    let Plan::Grid(grid) = Plan::parse(SMOKE).expect("parse smoke plan") else {
        panic!("smoke.plan must be a grid plan");
    };
    let trials = expand(&grid);
    let report = run_plan_text(SMOKE, 0).expect("run smoke plan");
    assert_eq!(report.kind, "grid");
    assert_eq!(report.rows, trials.len());
    assert_eq!(report.plan_hash, plan_hash(SMOKE));
    // one header line plus one line per trial, newline-terminated
    assert_eq!(report.jsonl.lines().count(), trials.len() + 1);
    assert!(report.jsonl.ends_with('\n'));
    let header = report.jsonl.lines().next().expect("header line");
    assert!(header.contains("\"row\":\"header\""), "first line must be the header: {header}");
    assert!(
        header.contains(&format!("\"plan_hash\":\"{:016x}\"", report.plan_hash)),
        "header must carry the plan hash: {header}"
    );
    // timings false: no per-stage wall-time fields anywhere
    assert!(!report.jsonl.contains("sketch_s"), "timings false must suppress stage times");
}
