//! Golden determinism for the experiment harness: the committed
//! `plans/smoke.plan` (16 trials, `timings false`) must produce
//! **byte-identical** JSONL on every run and at every runner thread
//! count. Trial seeds are pure functions of the trial coordinates and
//! runner parallelism never enters a trial's computation, so the
//! output is pinned by the plan text alone — the same contract the CI
//! smoke job re-checks with `cmp` on the real binary's output.

use rkc::experiment::{expand, plan_hash, run_plan_text, Plan};

const SMOKE: &str = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/plans/smoke.plan"));

#[test]
fn smoke_plan_jsonl_is_byte_identical_across_reruns_and_threads() {
    let first = run_plan_text(SMOKE, 1).expect("run smoke plan");
    let again = run_plan_text(SMOKE, 1).expect("rerun smoke plan");
    let parallel = run_plan_text(SMOKE, 4).expect("run smoke plan threaded");
    assert_eq!(first.jsonl, again.jsonl, "rerun diverged");
    assert_eq!(first.jsonl, parallel.jsonl, "threads=4 diverged from threads=1");
}

/// Observability is strictly out-of-band: the grid JSONL must be
/// byte-identical whether metric/span recording is on or off, and a
/// traced run must actually have filled the span ring. (Toggling the
/// process-wide switch is safe here — integration test binaries are
/// separate processes, and this is the only test in this binary that
/// touches it; it restores the default before returning.)
#[test]
fn smoke_plan_jsonl_is_byte_identical_with_tracing_on_or_off() {
    rkc::obs::set_enabled(true);
    rkc::obs::clear_trace();
    let traced = run_plan_text(SMOKE, 2).expect("run smoke plan traced");
    let (spans, _dropped) = rkc::obs::trace_snapshot();
    assert!(
        !spans.is_empty(),
        "a traced grid run must record fit spans (api.fit / pipeline.sketch_pass)"
    );
    assert!(
        spans.iter().any(|s| s.name == "api.fit"),
        "expected an api.fit span among {:?}",
        spans.iter().map(|s| s.name).collect::<std::collections::BTreeSet<_>>()
    );

    rkc::obs::set_enabled(false);
    let silent = run_plan_text(SMOKE, 2).expect("run smoke plan untraced");
    rkc::obs::set_enabled(true);

    assert_eq!(
        traced.jsonl, silent.jsonl,
        "recording on vs off changed the experiment output — obs leaked in-band"
    );
}

#[test]
fn smoke_plan_report_shape_matches_the_plan() {
    let Plan::Grid(grid) = Plan::parse(SMOKE).expect("parse smoke plan") else {
        panic!("smoke.plan must be a grid plan");
    };
    let trials = expand(&grid);
    let report = run_plan_text(SMOKE, 0).expect("run smoke plan");
    assert_eq!(report.kind, "grid");
    assert_eq!(report.rows, trials.len());
    assert_eq!(report.plan_hash, plan_hash(SMOKE));
    // one header line plus one line per trial, newline-terminated
    assert_eq!(report.jsonl.lines().count(), trials.len() + 1);
    assert!(report.jsonl.ends_with('\n'));
    let header = report.jsonl.lines().next().expect("header line");
    assert!(header.contains("\"row\":\"header\""), "first line must be the header: {header}");
    assert!(
        header.contains(&format!("\"plan_hash\":\"{:016x}\"", report.plan_hash)),
        "header must carry the plan hash: {header}"
    );
    // timings false: no per-stage wall-time fields anywhere
    assert!(!report.jsonl.contains("sketch_s"), "timings false must suppress stage times");
}
