//! Failure injection: the system must fail loudly and precisely on
//! corrupt inputs, missing artifacts, and misuse — never silently
//! produce wrong clusters.

use rkc::config::{Backend, ExperimentConfig, Method};
use rkc::coordinator::build_dataset;
use rkc::runtime::ArtifactRegistry;
use rkc::util::Json;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("rkc_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn registry_missing_dir_is_clean_error() {
    let err = match ArtifactRegistry::open("/nonexistent/rkc_artifacts") {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest.json"), "unhelpful error: {msg}");
}

#[test]
fn registry_corrupt_manifest_is_clean_error() {
    let d = tmpdir("corrupt_manifest");
    std::fs::write(d.join("manifest.json"), "{not json!").unwrap();
    let err = match ArtifactRegistry::open(d.to_str().unwrap()) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("manifest"));
}

#[test]
fn registry_manifest_must_be_array() {
    let d = tmpdir("manifest_obj");
    std::fs::write(d.join("manifest.json"), "{}").unwrap();
    let err = match ArtifactRegistry::open(d.to_str().unwrap()) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("array"));
}

#[test]
fn registry_unknown_artifact_lists_available() {
    let d = tmpdir("unknown_artifact");
    std::fs::write(
        d.join("manifest.json"),
        r#"[{"name":"a","file":"a.hlo.txt","params":{"op":"gram"},
            "inputs":[{"shape":[2,2],"dtype":"float32"}],
            "outputs":[{"shape":[2,2],"dtype":"float32"}]}]"#,
    )
    .unwrap();
    let reg = ArtifactRegistry::open(d.to_str().unwrap()).unwrap();
    let err = match reg.get("nope") {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("nope") && msg.contains('a'), "{msg}");
}

#[test]
fn registry_missing_hlo_file_is_clean_error() {
    let d = tmpdir("missing_hlo");
    std::fs::write(
        d.join("manifest.json"),
        r#"[{"name":"a","file":"a.hlo.txt","params":{"op":"gram"},
            "inputs":[{"shape":[2,2],"dtype":"float32"}],
            "outputs":[{"shape":[2,2],"dtype":"float32"}]}]"#,
    )
    .unwrap();
    let reg = ArtifactRegistry::open(d.to_str().unwrap()).unwrap();
    assert!(reg.get("a").is_err());
}

#[test]
fn registry_corrupt_hlo_text_is_clean_error() {
    let d = tmpdir("corrupt_hlo");
    std::fs::write(
        d.join("manifest.json"),
        r#"[{"name":"a","file":"a.hlo.txt","params":{"op":"gram"},
            "inputs":[{"shape":[2,2],"dtype":"float32"}],
            "outputs":[{"shape":[2,2],"dtype":"float32"}]}]"#,
    )
    .unwrap();
    std::fs::write(d.join("a.hlo.txt"), "HloModule garbage ENTRY {{{").unwrap();
    let reg = ArtifactRegistry::open(d.to_str().unwrap()).unwrap();
    assert!(reg.get("a").is_err());
}

#[test]
fn executable_rejects_wrong_arity() {
    // use the real artifacts (skip silently if not built)
    let Ok(reg) = ArtifactRegistry::open("artifacts") else { return };
    let Ok(exe) = reg.get("precond_n256_b64") else { return };
    let one_input = vec![rkc::runtime::Literal::vec1(&[0f32; 256 * 64])
        .reshape(&[256, 64])
        .unwrap()];
    let err = match exe.run(&one_input) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("expects 2 inputs"));
}

#[test]
fn xla_backend_without_registry_fails_loudly() {
    let mut cfg = ExperimentConfig::default();
    cfg.n = 64;
    cfg.dataset = "blobs".into();
    cfg.p = 4;
    cfg.k = 2;
    cfg.backend = Backend::Xla;
    cfg.method = Method::OnePass;
    let ds = build_dataset(&cfg).unwrap();
    let err = match rkc::coordinator::run_experiment(&cfg, &ds, None, 1) {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(format!("{err:#}").contains("registry"));
}

#[test]
fn config_rejects_unknown_keys_and_bad_values() {
    let mut cfg = ExperimentConfig::default();
    assert!(cfg.set("typo_key", "1").is_err());
    assert!(cfg.set("rank", "-3").is_err());
    assert!(cfg.set("kernel", "poly:abc:2").is_err());
    assert!(cfg.set("method", "nystrom_mNaN").is_err());
    // good values still work after failures
    cfg.set("rank", "4").unwrap();
    assert_eq!(cfg.rank, 4);
}

#[test]
fn errors_are_typed_not_stringly() {
    use rkc::error::RkcError;
    let mut cfg = ExperimentConfig::default();
    assert!(matches!(cfg.set("method", "warp_drive").unwrap_err(), RkcError::Parse { .. }));
    assert!(matches!(cfg.set("nope", "1").unwrap_err(), RkcError::InvalidConfig(_)));
    assert!(matches!(
        ArtifactRegistry::open("/nonexistent/rkc_artifacts").unwrap_err(),
        RkcError::Io { .. }
    ));
    cfg.dataset = "wat".into();
    assert!(matches!(build_dataset(&cfg).unwrap_err(), RkcError::Dataset(_)));
}

#[test]
fn dataset_csv_with_ragged_rows_is_rejected() {
    let d = tmpdir("ragged_csv");
    let p = d.join("bad.csv");
    std::fs::write(&p, "A,1.0,2.0\nB,3.0\n").unwrap();
    assert!(rkc::data::load_segmentation_csv(p.to_str().unwrap()).is_none());
}

#[test]
fn json_parser_does_not_panic_on_fuzz() {
    // quick deterministic fuzz: random byte strings must error, not panic
    use rkc::rng::{Pcg64, Rng};
    let mut rng = Pcg64::seed(42);
    for _ in 0..2000 {
        let len = rng.below(40);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(94) + 32) as u8).collect();
        let s = String::from_utf8(bytes).unwrap();
        let _ = Json::parse(&s); // must not panic
    }
}

// ---- serve-layer failure injection via the scenario replayer -------
//
// These drive the live HTTP front-end with `rkc::experiment`'s load
// replayer instead of hand-rolled sockets: the same code path `rkc
// experiment` runs in CI exercises the deadline, poisoning, and shed
// behaviors here.

use std::sync::Arc;
use std::time::Duration;

use rkc::api::KernelClusterer;
use rkc::data;
use rkc::experiment::{points_body, replay_scenario, ReplayTarget, ScenarioMode, ScenarioSpec};
use rkc::rng::Pcg64;
use rkc::serve::{serve_http_registry, HttpOpts, HttpServer, ModelRegistry, ServeOpts};

/// Fit one small model, serve it with the given front-end knobs, and
/// hand back the replay target plus a valid predict body.
fn serve_fixture(opts: HttpOpts) -> (HttpServer, ReplayTarget, String) {
    let ds = data::cross_lines(&mut Pcg64::seed(21), 128);
    let model = KernelClusterer::new(2).oversample(8).seed(3).threads(1).fit(&ds.x).expect("fit");
    let registry = Arc::new(ModelRegistry::new(ServeOpts { threads: 1, ..Default::default() }));
    registry.insert("m0", model).expect("register model");
    let http = serve_http_registry(registry, "127.0.0.1:0", opts).expect("serve http");
    let paths = vec!["/models/m0/predict".to_string()];
    let target = ReplayTarget { addr: http.local_addr(), paths };
    let body = points_body(&data::cross_lines(&mut Pcg64::seed(22), 4).x);
    (http, target, body)
}

/// Server-side counters settle asynchronously (a pool worker records
/// the failure after the client already moved on) — poll briefly.
fn wait_until(mut cond: impl FnMut() -> bool) -> bool {
    for _ in 0..100 {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    cond()
}

#[test]
fn slow_loris_is_cut_by_the_request_deadline_with_408() {
    let (http, target, body) = serve_fixture(HttpOpts {
        workers: 2,
        request_deadline: Duration::from_millis(300),
        ..Default::default()
    });
    let spec = ScenarioSpec {
        name: "loris".to_string(),
        mode: ScenarioMode::SlowLoris,
        clients: 1,
        requests: 2,
        rate_hz: 0.0,
        keep_alive: true,
    };
    let out = replay_scenario(&target, &spec, &body);
    assert_eq!(out.sent, 2);
    assert_eq!(out.count(408), 2, "deadline must answer 408: {:?}", out.statuses);
    assert_eq!(out.dropped, 0, "the 408 must arrive before the client read timeout");
    // the stalled connection was held for roughly the 300 ms deadline,
    // not the client's 10 s read timeout
    for &l in &out.latencies_s {
        assert!((0.2..5.0).contains(&l), "latency {l}s is not near the 300ms deadline");
    }
    http.shutdown();
}

#[test]
fn mid_body_disconnect_poisons_only_its_own_connection() {
    let (http, target, body) = serve_fixture(HttpOpts { workers: 2, ..Default::default() });
    let before = http.frontend_stats();
    let drip = ScenarioSpec {
        name: "drip".to_string(),
        mode: ScenarioMode::PartialWrite,
        clients: 1,
        requests: 2,
        rate_hz: 0.0,
        keep_alive: false,
    };
    let out = replay_scenario(&target, &drip, &body);
    // each nominal request is one aborted write plus one good follow-up
    assert_eq!(out.sent, 4);
    assert_eq!(out.ok, 2, "follow-up requests must succeed: {:?}", out.statuses);
    assert_eq!(out.dropped, 2);
    // both aborted bodies surface as framing failures on the server —
    // and nothing else does
    assert!(
        wait_until(|| http.frontend_stats().failures - before.failures >= 2),
        "server never recorded the two aborted bodies as failures"
    );
    assert_eq!(http.frontend_stats().failures - before.failures, 2);
    // the registry is still fully alive afterwards
    let steady = ScenarioSpec {
        name: "steady".to_string(),
        mode: ScenarioMode::OpenLoop,
        clients: 2,
        requests: 3,
        rate_hz: 0.0,
        keep_alive: true,
    };
    let again = replay_scenario(&target, &steady, &body);
    assert_eq!(again.ok, 6, "poison must not outlive its connection: {:?}", again.statuses);
    http.shutdown();
}

#[test]
fn burst_beyond_the_connection_queue_records_sheds() {
    let (http, target, body) = serve_fixture(HttpOpts {
        workers: 1,
        backlog: 1,
        keep_alive: Duration::ZERO,
        ..Default::default()
    });
    let before = http.frontend_stats();
    let spike = ScenarioSpec {
        name: "spike".to_string(),
        mode: ScenarioMode::Burst,
        clients: 4,
        requests: 1,
        rate_hz: 0.0,
        keep_alive: false,
    };
    let out = replay_scenario(&target, &spike, &body);
    let shed = http.frontend_stats().shed - before.shed;
    assert!(shed >= 2, "backlog 1 must shed most of a 4-connection spike (shed {shed})");
    assert_eq!(out.sent, 4);
    assert_eq!(out.ok as u64, 4 - shed, "admitted connections must be served: {:?}", out.statuses);
    assert_eq!(
        out.count(503) as u64 + out.dropped as u64,
        shed,
        "every shed connection must be observed as a 503 or a dead socket: {:?}",
        out.statuses
    );
    http.shutdown();
}

#[test]
fn sketch_ingest_shape_mismatch_panics_with_context() {
    use rkc::lowrank::OnePassSketch;
    use rkc::rng::Pcg64;
    use rkc::sketch::Srht;
    let mut rng = Pcg64::seed(1);
    let srht = Srht::draw(&mut rng, 16, 4);
    let mut sk = OnePassSketch::new(srht, 10);
    let bad = rkc::linalg::Mat::zeros(2, 3); // wrong r' width
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        sk.ingest(&[0, 1], &bad);
    }));
    assert!(result.is_err());
}
