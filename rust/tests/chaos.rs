//! Chaos capstone: the crash-safety story end to end. Failpoints fire
//! on the real IO edges while the PR-7 load replayer drives the live
//! HTTP front-end, a mid-stream "kill" is recovered from a durable
//! `.rkcs` checkpoint, and corrupt persisted bytes of both formats are
//! swept through truncations and bit flips. The invariants:
//!
//! - a resumed stream's refreshed model is **bit-identical** to an
//!   uninterrupted run over the same chunk sequence;
//! - request accounting stays exact while connections are being
//!   dropped (`ok + dropped + non-2xx == sent`, nothing double-counted);
//! - a failed hot-swap quarantines the name and degrades `/healthz`
//!   but the previous generation keeps answering;
//! - corrupt `.rkc`/`.rkcs` bytes are typed errors, never panics;
//! - with `RKC_FAULTS` unset the fault layer is invisible: the golden
//!   experiment JSONL is byte-identical, armed or not.

use std::net::SocketAddr;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use rkc::api::{FittedModel, KernelClusterer};
use rkc::bench_harness::MiniHttpClient;
use rkc::data;
use rkc::error::RkcError;
use rkc::experiment::{points_body, replay_scenario, run_plan_text, ReplayTarget, ScenarioMode, ScenarioSpec};
use rkc::linalg::Mat;
use rkc::rng::{Pcg64, Rng};
use rkc::serve::{serve_http_registry, HttpOpts, ModelRegistry, ServeOpts};
use rkc::stream::StreamClusterer;
use rkc::util::Json;

/// The fault table is process-global, and the crate-internal test
/// guard is not visible to integration tests — this binary serializes
/// every test on its own lock instead (each one either arms faults or
/// writes through a fault-instrumented path).
static FAULTS: Mutex<()> = Mutex::new(());

fn fault_lock() -> MutexGuard<'static, ()> {
    let guard = FAULTS.lock().unwrap_or_else(|p| p.into_inner());
    // a previous test that failed mid-arm must not leak its faults in
    rkc::fault::clear();
    guard
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("rkc_chaos_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn column_slice(x: &Mat, lo: usize, m: usize) -> Mat {
    Mat::from_fn(x.rows(), m, |i, j| x[(i, lo + j)])
}

/// `.rkc` bytes with the wall-clock timing metrics zeroed — they
/// measure the run, not the model, and are the only bytes allowed to
/// differ between a resumed and an uninterrupted fit.
fn canonical_bytes(model: &mut FittedModel) -> Vec<u8> {
    let m = model.metrics_mut();
    m.sketch_time = Duration::ZERO;
    m.recovery_time = Duration::ZERO;
    m.kmeans_time = Duration::ZERO;
    rkc::model_io::model_to_bytes(model)
}

/// One hand-framed `Connection: close` GET that tolerates the server
/// dropping the connection (accept-faulted runs): `None` when the dial
/// or the response never lands.
fn try_get(addr: SocketAddr, path: &str) -> Option<(u16, String)> {
    let mut c = MiniHttpClient::connect_with_retry(addr, 3, Duration::from_millis(5))?;
    c.send_raw(
        format!("GET {path} HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\nConnection: close\r\n\r\n")
            .as_bytes(),
    );
    c.read_response()
}

fn healthz(addr: SocketAddr) -> Json {
    for _ in 0..50 {
        if let Some((status, body)) = try_get(addr, "/healthz") {
            assert!(status == 200 || status == 503, "unexpected /healthz status {status}");
            return Json::parse(&body).expect("healthz must be JSON");
        }
    }
    panic!("/healthz never answered");
}

// ---------------------------------------------------------------------------

/// Acceptance gate: with no spec armed the fault layer must be
/// invisible — and an armed spec that names no production site must be
/// invisible too (the armed fast path cannot leak into the math).
#[test]
fn golden_experiment_is_byte_identical_with_fault_layer_present() {
    let _g = fault_lock();
    const SMOKE: &str = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/plans/smoke.plan"));
    assert!(!rkc::fault::armed(), "no test may leak an armed fault table");
    let clean = run_plan_text(SMOKE, 2).expect("clean run");
    rkc::fault::configure("chaos.nop=io_error:1.0").unwrap();
    assert!(rkc::fault::armed());
    let armed = run_plan_text(SMOKE, 2).expect("armed run");
    rkc::fault::clear();
    assert_eq!(
        clean.jsonl, armed.jsonl,
        "an armed fault table with no production site must not change the experiment output"
    );
}

/// Corruption sweep across BOTH persisted formats: every truncation and
/// every bit flip must surface as a typed error — never a panic, never
/// a silently wrong model/state.
#[test]
fn corrupt_rkc_and_rkcs_bytes_are_typed_errors_never_panics() {
    let _g = fault_lock();
    let ds = data::cross_lines(&mut Pcg64::seed(51), 96);
    let model =
        KernelClusterer::new(2).oversample(8).seed(5).threads(1).fit(&ds.x).expect("fit");
    let mut sc = StreamClusterer::new(2).oversample(8).seed(5).threads(1).capacity(96);
    sc.ingest(&ds.x).unwrap();
    sc.refresh().unwrap();

    let sweeps: [(&str, Vec<u8>); 2] = [
        ("model.rkc", rkc::model_io::model_to_bytes(&model)),
        ("state.rkcs", sc.state_to_bytes()),
    ];
    for (origin, bytes) in &sweeps {
        let parse = |b: &[u8]| -> Option<String> {
            let err = if origin.ends_with(".rkcs") {
                StreamClusterer::state_from_bytes(b, origin).err()
            } else {
                rkc::model_io::model_from_bytes(b, origin).err()
            };
            err.map(|e| format!("{e:#}"))
        };
        assert!(parse(bytes).is_none(), "{origin}: pristine bytes must load");

        // truncations at and around every structural boundary
        let n = bytes.len();
        for cut in [0, 4, 8, 12, 16, n / 4, n / 2, 3 * n / 4, n - 9, n - 1] {
            let msg = parse(&bytes[..cut]);
            assert!(msg.is_some(), "{origin}: truncation at {cut}/{n} must be rejected");
        }
        // deterministic scattered bit flips — the trailing checksum
        // must catch every one of them
        let mut rng = Pcg64::seed(0xf11f);
        for _ in 0..32 {
            let mut c = bytes.clone();
            let bit = rng.below(n * 8);
            c[bit / 8] ^= 1 << (bit % 8);
            assert!(
                parse(&c).is_some(),
                "{origin}: flipped bit {bit} must be rejected"
            );
        }
    }
}

/// Graceful degradation over the wire: a hot-swap that keeps failing
/// under an armed `serve.load` fault answers 503, quarantines the name
/// in a `degraded` /healthz, and leaves the previous generation
/// serving; clearing the fault and retrying recovers to `ok`.
#[test]
fn failed_hot_swap_degrades_healthz_and_previous_generation_keeps_serving() {
    let _g = fault_lock();
    let d = tmpdir("swap");
    let ds = data::cross_lines(&mut Pcg64::seed(61), 96);
    let model =
        KernelClusterer::new(2).oversample(8).seed(6).threads(1).fit(&ds.x).expect("fit");
    let update =
        KernelClusterer::new(2).oversample(8).seed(7).threads(1).fit(&ds.x).expect("fit");
    let path = d.join("update.rkc");
    rkc::model_io::save_model(&update, path.to_str().unwrap()).unwrap();

    let registry = Arc::new(ModelRegistry::new(ServeOpts { threads: 1, ..Default::default() }));
    registry.insert("m0", model).unwrap();
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts { workers: 2, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr();
    let body = points_body(&data::cross_lines(&mut Pcg64::seed(62), 5).x);
    let put = format!("{{\"path\":\"{}\"}}", path.display());

    let mut c = MiniHttpClient::connect(addr);
    let (status, baseline) = c.request("POST", "/models/m0/predict", &body);
    assert_eq!(status, 200);

    rkc::fault::configure("serve.load=io_error:1.0").unwrap();
    let (status, resp) = c.request("PUT", "/models/m0", &put);
    assert_eq!(status, 503, "exhausted transient retries must answer 503: {resp}");

    // degraded, name quarantined — but the old generation still answers
    let h = healthz(addr);
    assert_eq!(h.str_field("status").unwrap(), "degraded", "{h}");
    let Some(Json::Obj(q)) = h.get("quarantined") else { panic!("no quarantined field: {h}") };
    assert!(q.contains_key("m0"), "{h}");
    let (status, still) = c.request("POST", "/models/m0/predict", &body);
    assert_eq!(status, 200, "previous generation must keep serving");
    assert_eq!(still, baseline, "serving must not see a half-swapped model");

    // the injected trips and the retry/quarantine counters are observable
    let (status, metrics) = c.request("GET", "/metrics", "");
    assert_eq!(status, 200);
    for needle in
        ["rkc_fault_trips_total", "rkc_serve_load_retries_total", "rkc_models_quarantined_total"]
    {
        assert!(metrics.contains(needle), "/metrics lost {needle}");
    }

    // clearing the fault and retrying the swap recovers to ok
    rkc::fault::clear();
    let (status, resp) = c.request("PUT", "/models/m0", &put);
    assert_eq!(status, 200, "swap after clearing faults must succeed: {resp}");
    let h = healthz(addr);
    assert_eq!(h.str_field("status").unwrap(), "ok", "{h}");
    http.shutdown();
}

/// Accept-fault chaos under the PR-7 load replayer: connections are
/// dropped server-side mid-run, yet the outcome ledger stays exact —
/// every attempt is observed exactly once, as a response or a drop.
#[test]
fn load_replay_accounting_is_exact_while_accept_faults_drop_connections() {
    let _g = fault_lock();
    let ds = data::cross_lines(&mut Pcg64::seed(71), 96);
    let model =
        KernelClusterer::new(2).oversample(8).seed(8).threads(1).fit(&ds.x).expect("fit");
    let registry = Arc::new(ModelRegistry::new(ServeOpts { threads: 1, ..Default::default() }));
    registry.insert("m0", model).unwrap();
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts { workers: 2, ..Default::default() },
    )
    .unwrap();
    let target =
        ReplayTarget { addr: http.local_addr(), paths: vec!["/models/m0/predict".to_string()] };
    let body = points_body(&data::cross_lines(&mut Pcg64::seed(72), 4).x);

    rkc::fault::configure("http.accept=io_error:0.5").unwrap();
    let spec = ScenarioSpec {
        name: "chaos".to_string(),
        mode: ScenarioMode::OpenLoop,
        clients: 4,
        requests: 8,
        rate_hz: 0.0,
        keep_alive: false,
    };
    let out = replay_scenario(&target, &spec, &body);
    rkc::fault::clear();

    assert_eq!(out.sent, 32);
    let answered: usize = out.statuses.values().sum();
    assert_eq!(
        answered + out.dropped,
        out.sent,
        "every attempt must be a response or a drop, exactly once: {:?}",
        out.statuses
    );
    assert_eq!(out.ok, answered, "admitted requests must succeed: {:?}", out.statuses);
    assert!(out.dropped >= 1, "p=0.5 over 32 connections must drop some");
    assert!(out.ok >= 1, "p=0.5 over 32 connections must admit some");

    // the server itself is unharmed: next connection, clean 200
    let h = healthz(http.local_addr());
    assert_eq!(h.str_field("status").unwrap(), "ok", "{h}");
    http.shutdown();
}

/// The kill -9 story end to end, with delay faults stretching the
/// durable-write windows: a stream checkpointed mid-run and "killed"
/// resumes from the `.rkcs` file and finishes with a model that is
/// bit-identical to an uninterrupted run — and serves byte-identical
/// responses. A checkpoint attempt that faults leaves no file behind.
#[test]
fn killed_stream_resumes_bit_identical_and_serves_identically() {
    let _g = fault_lock();
    let d = tmpdir("resume");
    let state = d.join("state.rkcs");
    let state = state.to_str().unwrap();
    let ds = data::cross_lines(&mut Pcg64::seed(81), 240);
    let chunk = 48;
    let build = || {
        StreamClusterer::new(2)
            .oversample(8)
            .seed(34)
            .threads(1)
            .capacity(240)
    };

    // reference: one uninterrupted process, refreshes after chunks 2 and 5
    let mut uninterrupted = build();
    let mut reference: Option<FittedModel> = None;
    for c in 0..5 {
        uninterrupted.ingest(&column_slice(&ds.x, c * chunk, chunk)).unwrap();
        if c == 1 || c == 4 {
            reference = Some(uninterrupted.refresh().unwrap());
        }
    }

    // chaos: same schedule, but the process "dies" after chunk 3 —
    // with the durable-write failpoints armed as pure delays, so the
    // checkpoint/fsync windows are actually open when it happens
    rkc::fault::configure("model_io.fsync=delay_ms:1:0.5,stream.checkpoint=delay_ms:1:0.5")
        .unwrap();
    let mut sc = build();
    for c in 0..3 {
        sc.ingest(&column_slice(&ds.x, c * chunk, chunk)).unwrap();
        if c == 1 {
            sc.refresh().unwrap();
        }
    }
    // a checkpoint that faults is a typed transient error and leaves
    // nothing on disk
    rkc::fault::configure("stream.checkpoint=io_error:1.0").unwrap();
    let err = sc.checkpoint(state).unwrap_err();
    assert!(matches!(err, RkcError::Transient { .. }), "{err}");
    assert!(!std::path::Path::new(state).exists(), "failed checkpoint must leave no file");
    rkc::fault::configure("model_io.fsync=delay_ms:1:0.5,stream.checkpoint=delay_ms:1:0.5")
        .unwrap();
    sc.checkpoint(state).unwrap();
    drop(sc); // the kill

    let mut resumed = StreamClusterer::resume(state).unwrap();
    assert_eq!(resumed.n_points(), 3 * chunk);
    assert_eq!(resumed.refreshes(), 1);
    for c in 3..5 {
        resumed.ingest(&column_slice(&ds.x, c * chunk, chunk)).unwrap();
    }
    let mut final_model = resumed.refresh().unwrap();
    rkc::fault::clear();

    let mut reference = reference.expect("reference refresh ran");
    assert_eq!(
        canonical_bytes(&mut reference),
        canonical_bytes(&mut final_model),
        "resumed model must be bit-identical to the uninterrupted run"
    );

    // and the two models answer the wire byte-identically
    let query = points_body(&data::cross_lines(&mut Pcg64::seed(82), 6).x);
    let mut responses = Vec::new();
    for model in [reference, final_model] {
        let registry =
            Arc::new(ModelRegistry::new(ServeOpts { threads: 1, ..Default::default() }));
        registry.insert("stream", model).unwrap();
        let http = serve_http_registry(
            Arc::clone(&registry),
            "127.0.0.1:0",
            HttpOpts { workers: 1, ..Default::default() },
        )
        .unwrap();
        let mut c = MiniHttpClient::connect(http.local_addr());
        let (status, resp) = c.request("POST", "/models/stream/embed", &query);
        assert_eq!(status, 200, "{resp}");
        responses.push(resp);
        http.shutdown();
    }
    assert_eq!(responses[0], responses[1], "resumed model must serve identical bytes");
}
