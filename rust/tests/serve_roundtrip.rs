//! End-to-end serving contract: a model fitted in memory, saved to the
//! `.rkc` format, reloaded, and queried through `ModelServer` — both
//! in-process and over the HTTP front-end with concurrent clients —
//! returns predictions bit-identical to `FittedModel::predict` on the
//! original. Malformed requests get typed 4xx responses, never a crash.
//!
//! The keep-alive/registry tests extend the same contract to the
//! multi-model front-end: several requests ride one persistent
//! connection, a framing failure poisons only its own connection (the
//! pool worker survives), and N concurrent keep-alive clients hitting
//! two registry models stay bit-identical to in-memory predict.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use rkc::api::{FittedModel, KernelClusterer};
use rkc::bench_harness::MiniHttpClient;
use rkc::config::Method;
use rkc::data;
use rkc::error::RkcError;
use rkc::linalg::Mat;
use rkc::rng::Pcg64;
use rkc::serve::{serve_http, serve_http_registry, HttpOpts, ModelRegistry, ModelServer, ServeOpts};
use rkc::util::Json;

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rkc_serve_roundtrip_{}_{tag}.rkc", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// Minimal HTTP/1.1 client used by the tests (and mirrored by the CI
/// smoke step): one request per connection, JSON in and out.
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).expect("connecting to the serve front-end");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: rkc\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut resp = String::new();
    stream.read_to_string(&mut resp).unwrap();
    let status: u16 = resp
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let body = resp
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn points_json(x: &Mat) -> String {
    let pts: Vec<Json> = (0..x.cols())
        .map(|j| Json::Arr((0..x.rows()).map(|i| Json::Num(x[(i, j)])).collect()))
        .collect();
    Json::Obj(BTreeMap::from([("points".to_string(), Json::Arr(pts))])).to_string()
}

fn labels_from(body: &str) -> Vec<usize> {
    Json::parse(body)
        .expect("response is JSON")
        .get("labels")
        .expect("has labels")
        .as_arr()
        .expect("labels is an array")
        .iter()
        .map(|j| j.as_usize().expect("label is an integer"))
        .collect()
}

#[test]
fn saved_reloaded_served_predictions_are_bit_identical() {
    for (tag, method) in [("one_pass", Method::OnePass), ("nystrom", Method::Nystrom { m: 40 })] {
        let train = data::cross_lines(&mut Pcg64::seed(71), 256);
        let model = KernelClusterer::new(2)
            .method(method)
            .rank(2)
            .oversample(8)
            .seed(19)
            .fit(&train.x)
            .unwrap();
        let query = data::cross_lines(&mut Pcg64::seed(72), 48).x;
        let want = model.predict(&query).unwrap();
        let want_embed = model.embed(&query).unwrap();

        // save → reload: bit-identical predictions and embeddings
        let path = tmp_path(tag);
        model.save(&path).unwrap();
        let loaded = FittedModel::load(&path).unwrap();
        assert_eq!(loaded.labels(), model.labels(), "{tag}");
        assert_eq!(loaded.predict(&query).unwrap(), want, "{tag}");
        assert_eq!(
            loaded.embed(&query).unwrap().data(),
            want_embed.data(),
            "{tag}: reloaded embedding bits"
        );

        // in-process serving, 2 concurrent clients through the batcher
        let server =
            ModelServer::new(loaded, ServeOpts { max_batch: 4, ..Default::default() }).unwrap();
        std::thread::scope(|s| {
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let h = server.handle();
                    let q = query.clone();
                    s.spawn(move || h.predict(q).unwrap())
                })
                .collect();
            for w in workers {
                assert_eq!(w.join().unwrap(), want, "{tag}: served != direct");
            }
        });

        // HTTP front-end, 2 concurrent clients
        let http = serve_http(&server, "127.0.0.1:0").unwrap();
        let addr = http.local_addr();
        let body = points_json(&query);
        std::thread::scope(|s| {
            let clients: Vec<_> = (0..2)
                .map(|_| {
                    let b = body.clone();
                    s.spawn(move || http_request(addr, "POST", "/predict", &b))
                })
                .collect();
            for c in clients {
                let (status, resp) = c.join().unwrap();
                assert_eq!(status, 200, "{tag}: {resp}");
                assert_eq!(labels_from(&resp), want, "{tag}: http != direct");
            }
        });

        // the embedding travels bit-exactly through JSON too (shortest
        // round-trip float formatting on both sides)
        let (status, resp) = http_request(addr, "POST", "/embed", &body);
        assert_eq!(status, 200, "{tag}: {resp}");
        let emb = Json::parse(&resp).unwrap();
        let emb = emb.get("embedding").unwrap().as_arr().unwrap();
        assert_eq!(emb.len(), query.cols(), "{tag}");
        for (j, point) in emb.iter().enumerate() {
            let coords = point.as_arr().unwrap();
            assert_eq!(coords.len(), want_embed.rows(), "{tag}");
            for (i, c) in coords.iter().enumerate() {
                let got = c.as_f64().unwrap();
                let want_v = want_embed[(i, j)];
                // strict bit equality: Json Display preserves even the
                // sign of an exact zero ("-0"), so no exemptions needed
                assert_eq!(
                    got.to_bits(),
                    want_v.to_bits(),
                    "{tag}: embedding[{i},{j}] differs over HTTP: {got} vs {want_v}"
                );
            }
        }

        // malformed requests: typed 4xx, server stays alive
        let (status, resp) = http_request(addr, "POST", "/predict", "{definitely not json");
        assert_eq!(status, 400, "{tag}: {resp}");
        assert!(resp.contains("error"), "{tag}: {resp}");
        let (status, _) = http_request(addr, "POST", "/predict", r#"{"points": [[1, 2], [3]]}"#);
        assert_eq!(status, 400, "{tag}: ragged points");
        let (status, _) = http_request(addr, "GET", "/predict", "");
        assert_eq!(status, 405, "{tag}: GET /predict");
        let (status, _) = http_request(addr, "POST", "/nope", "{}");
        assert_eq!(status, 404, "{tag}");

        // still serving correctly after the bad requests
        let (status, resp) = http_request(addr, "POST", "/predict", &body);
        assert_eq!(status, 200, "{tag}");
        assert_eq!(labels_from(&resp), want, "{tag}: survives bad input");

        // health endpoint reports the counters
        let (status, resp) = http_request(addr, "GET", "/healthz", "");
        assert_eq!(status, 200, "{tag}");
        let health = Json::parse(&resp).unwrap();
        assert_eq!(health.get("status").unwrap().as_str().unwrap(), "ok", "{tag}");
        assert!(health.get("requests").unwrap().as_f64().unwrap() >= 3.0, "{tag}");
        assert!(health.get("http_requests").unwrap().as_f64().unwrap() >= 7.0, "{tag}");
        assert!(health.get("http_failures").unwrap().as_f64().unwrap() >= 4.0, "{tag}");

        http.shutdown();
        server.shutdown();
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn corrupt_and_future_model_files_are_typed_errors_at_the_api_surface() {
    let train = data::cross_lines(&mut Pcg64::seed(73), 96);
    let model = KernelClusterer::new(2).oversample(8).seed(5).fit(&train.x).unwrap();
    let path = tmp_path("corrupt");
    model.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();

    // truncated payload
    std::fs::write(&path, &bytes[..bytes.len() - 32]).unwrap();
    assert!(matches!(FittedModel::load(&path).unwrap_err(), RkcError::Model { .. }));

    // corrupt header byte → checksum mismatch
    let mut corrupted = bytes.clone();
    corrupted[20] ^= 0xff;
    std::fs::write(&path, &corrupted).unwrap();
    let err = FittedModel::load(&path).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");

    // a file claiming a future format version, re-sealed so only the
    // version check fires
    bytes[8..12].copy_from_slice(&7u32.to_le_bytes());
    let end = bytes.len() - 8;
    let ck = rkc::model_io::checksum(&bytes[..end]);
    bytes[end..].copy_from_slice(&ck.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    assert!(matches!(
        FittedModel::load(&path).unwrap_err(),
        RkcError::ModelVersion { found: 7, .. }
    ));

    std::fs::remove_file(&path).unwrap();
}

#[test]
fn plain_kmeans_models_serve_too() {
    // the input-space assigner has no embedding; predict works, embed is
    // a per-request typed error that the server survives
    let ds = data::gaussian_blobs(&mut Pcg64::seed(74), 90, 3, 3, 0.3);
    let model = KernelClusterer::new(3)
        .method(Method::PlainKmeans)
        .seed(2)
        .fit(&ds.x)
        .unwrap();
    let want = model.predict(&ds.x).unwrap();
    let path = tmp_path("plain");
    model.save(&path).unwrap();
    let server =
        ModelServer::new(FittedModel::load(&path).unwrap(), ServeOpts::default()).unwrap();
    let h = server.handle();
    assert!(h.embed(ds.x.clone()).is_err());
    assert_eq!(h.predict(ds.x.clone()).unwrap(), want);

    let http = serve_http(&server, "127.0.0.1:0").unwrap();
    let body = points_json(&ds.x);
    let (status, resp) = http_request(http.local_addr(), "POST", "/embed", &body);
    assert_eq!(status, 400, "embed on a plain model is a client error: {resp}");
    let (status, resp) = http_request(http.local_addr(), "POST", "/predict", &body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(labels_from(&resp), want);
    http.shutdown();
    server.shutdown();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_socket() {
    let train = data::cross_lines(&mut Pcg64::seed(75), 128);
    let model = KernelClusterer::new(2).oversample(8).seed(13).fit(&train.x).unwrap();
    let query = data::cross_lines(&mut Pcg64::seed(76), 9).x;
    let want = model.predict(&query).unwrap();
    let server = ModelServer::new(model, ServeOpts::default()).unwrap();
    let http = serve_http(&server, "127.0.0.1:0").unwrap();

    let body = points_json(&query);
    let mut client = MiniHttpClient::connect(http.local_addr());
    for round in 0..3 {
        let (status, resp) = client.request("POST", "/predict", &body);
        assert_eq!(status, 200, "round {round}: {resp}");
        assert_eq!(labels_from(&resp), want, "round {round}");
    }
    // reuse is visible in the front-end counters: 3 requests, 1 connection
    let fe = http.frontend_stats();
    assert_eq!(fe.connections, 1, "all requests must ride one connection");
    assert!(fe.requests >= 3, "{}", fe.requests);
    assert_eq!(fe.failures, 0);

    // an explicit Connection: close is honored mid-stream
    client.send_raw(
        format!(
            "POST /predict HTTP/1.1\r\nHost: rkc\r\nConnection: close\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    );
    let (status, resp) = client.read_response().expect("final response");
    assert_eq!(status, 200, "{resp}");
    assert_eq!(labels_from(&resp), want);
    client.assert_closed();

    http.shutdown();
    server.shutdown();
}

#[test]
fn malformed_second_request_poisons_only_its_connection() {
    let train = data::cross_lines(&mut Pcg64::seed(77), 128);
    let model = KernelClusterer::new(2).oversample(8).seed(17).fit(&train.x).unwrap();
    let query = data::cross_lines(&mut Pcg64::seed(78), 7).x;
    let want = model.predict(&query).unwrap();
    let server = ModelServer::new(model, ServeOpts::default()).unwrap();
    let http = serve_http(&server, "127.0.0.1:0").unwrap();
    let addr = http.local_addr();
    let body = points_json(&query);

    let mut poisoned = MiniHttpClient::connect(addr);
    let (status, _) = poisoned.request("POST", "/predict", &body);
    assert_eq!(status, 200);
    // a request line with no path cannot be re-framed: the server must
    // answer 400 and close THIS connection only
    poisoned.send_raw(b"NONSENSE\r\n\r\n");
    let (status, resp) = poisoned.read_response().expect("400 before the close");
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("error"), "{resp}");
    poisoned.assert_closed();

    // the pool worker survived and serves fresh connections
    let mut fresh = MiniHttpClient::connect(addr);
    let (status, resp) = fresh.request("POST", "/predict", &body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(labels_from(&resp), want);

    // an app-level error (bad JSON body, framing intact) does NOT close
    let (status, _) = fresh.request("POST", "/predict", "{not json");
    assert_eq!(status, 400);
    let (status, resp) = fresh.request("POST", "/predict", &body);
    assert_eq!(status, 200, "connection survives an app-level 400: {resp}");

    // conflicting Content-Length headers are a smuggling-grade framing
    // hazard on a persistent connection: 400, then close
    let mut smuggler = MiniHttpClient::connect(addr);
    smuggler.send_raw(
        b"POST /predict HTTP/1.1\r\nHost: rkc\r\nContent-Length: 2\r\n\
          Content-Length: 5\r\n\r\n{}xyz",
    );
    let (status, resp) = smuggler.read_response().expect("400 before the close");
    assert_eq!(status, 400, "{resp}");
    assert!(resp.contains("content-length"), "{resp}");
    smuggler.assert_closed();

    http.shutdown();
    server.shutdown();
}

#[test]
fn registry_serves_two_models_concurrently_bit_identical_over_keep_alive() {
    // two deliberately different models (k=2 rings vs k=3 blobs, same
    // input dimension) so any routing mix-up shows up as a label diff
    let train_a = data::cross_lines(&mut Pcg64::seed(81), 192);
    let model_a = KernelClusterer::new(2).oversample(8).seed(3).fit(&train_a.x).unwrap();
    let train_b = data::gaussian_blobs(&mut Pcg64::seed(82), 150, 2, 3, 0.4);
    let model_b = KernelClusterer::new(3).oversample(8).seed(4).fit(&train_b.x).unwrap();
    let query = data::cross_lines(&mut Pcg64::seed(83), 23).x;
    let want_a = model_a.predict(&query).unwrap();
    let want_b = model_b.predict(&query).unwrap();

    let registry =
        Arc::new(ModelRegistry::new(ServeOpts { max_batch: 4, ..Default::default() }));
    registry.insert("rings", model_a).unwrap();
    registry.insert("blobs", model_b).unwrap();
    let http = serve_http_registry(
        Arc::clone(&registry),
        "127.0.0.1:0",
        HttpOpts { workers: 4, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr();
    let body = points_json(&query);

    std::thread::scope(|s| {
        let clients: Vec<_> = (0..4)
            .map(|_| {
                let b = body.clone();
                let want_a = &want_a;
                let want_b = &want_b;
                s.spawn(move || {
                    let mut c = MiniHttpClient::connect(addr);
                    for i in 0..6 {
                        let (path, want) = if i % 2 == 0 {
                            ("/models/rings/predict", want_a)
                        } else {
                            ("/models/blobs/predict", want_b)
                        };
                        let (status, resp) = c.request("POST", path, &b);
                        assert_eq!(status, 200, "{path}: {resp}");
                        assert_eq!(&labels_from(&resp), want, "{path}: served != in-memory");
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
    });

    // 4 keep-alive connections carried 24 requests between them
    let fe = http.frontend_stats();
    assert_eq!(fe.connections, 4);
    assert!(fe.requests >= 24, "{}", fe.requests);
    // per-model stats stayed separate: 12 routed requests each, no errors
    for info in registry.list() {
        assert_eq!(info.stats.http_requests, 12, "{}", info.name);
        assert_eq!(info.stats.requests, 12, "{}", info.name);
        assert_eq!(info.stats.errors, 0, "{}", info.name);
        assert!(info.stats.queue_highwater >= 1, "{}", info.name);
    }
    http.shutdown();
}

#[test]
fn registry_admin_load_unload_and_404_over_http() {
    let train = data::cross_lines(&mut Pcg64::seed(91), 160);
    let model = KernelClusterer::new(2).oversample(8).seed(7).fit(&train.x).unwrap();
    let query = data::cross_lines(&mut Pcg64::seed(92), 11).x;
    let want = model.predict(&query).unwrap();
    let path = tmp_path("admin");
    model.save(&path).unwrap();

    let registry = Arc::new(ModelRegistry::new(ServeOpts::default()));
    registry.insert("base", model).unwrap();
    let http =
        serve_http_registry(Arc::clone(&registry), "127.0.0.1:0", HttpOpts::default()).unwrap();
    let addr = http.local_addr();
    let body = points_json(&query);

    // unknown names are 404 with a JSON error body
    let (status, resp) = http_request(addr, "POST", "/models/ghost/predict", &body);
    assert_eq!(status, 404, "{resp}");
    assert!(Json::parse(&resp).unwrap().get("error").is_some(), "{resp}");

    // runtime PUT-load under a new name; it serves the same bits
    let put = format!(r#"{{"path": "{path}"}}"#);
    let (status, resp) = http_request(addr, "PUT", "/models/extra", &put);
    assert_eq!(status, 200, "{resp}");
    let (status, resp) = http_request(addr, "POST", "/models/extra/predict", &body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(labels_from(&resp), want);

    // the listing shows both, with the first-registered model as default
    let (status, resp) = http_request(addr, "GET", "/models", "");
    assert_eq!(status, 200);
    let listing = Json::parse(&resp).unwrap();
    assert_eq!(listing.get("models").unwrap().as_arr().unwrap().len(), 2, "{resp}");
    assert_eq!(listing.get("default").unwrap().as_str().unwrap(), "base", "{resp}");
    let (status, resp) = http_request(addr, "GET", "/models/extra", "");
    assert_eq!(status, 200);
    assert_eq!(Json::parse(&resp).unwrap().str_field("method").unwrap(), "one_pass");

    // DELETE unloads; the name 404s afterwards (and double-DELETE 404s)
    let (status, _) = http_request(addr, "DELETE", "/models/extra", "");
    assert_eq!(status, 200);
    let (status, _) = http_request(addr, "POST", "/models/extra/predict", &body);
    assert_eq!(status, 404);
    let (status, _) = http_request(addr, "DELETE", "/models/extra", "");
    assert_eq!(status, 404);

    // bad admin input: missing file 404s, bad name 400s, bad body 400s
    let (status, _) =
        http_request(addr, "PUT", "/models/extra", r#"{"path": "/nonexistent/m.rkc"}"#);
    assert_eq!(status, 404);
    let (status, _) = http_request(addr, "PUT", "/models/bad$name", &put);
    assert_eq!(status, 400);
    let (status, _) = http_request(addr, "PUT", "/models/extra2", "{nope");
    assert_eq!(status, 400);

    // legacy aliases keep hitting the default model
    let (status, resp) = http_request(addr, "POST", "/predict", &body);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(labels_from(&resp), want);

    http.shutdown();
    std::fs::remove_file(&path).unwrap();
}
