//! Property-based tests over the crate's core invariants.
//!
//! No external proptest crate on this offline image, so properties are
//! driven by the crate's own deterministic PCG64 across many random
//! instances — same idea, explicit seeds, fully reproducible failures.

use std::collections::BTreeSet;

use rkc::clustering::{accuracy, adjusted_rand_index, kernel_kmeans_objective, kmeans, KmeansOpts};
use rkc::config::Method;
use rkc::data;
use rkc::error::RkcError;
use rkc::experiment::{expand, trial_seed, GridPlan, LoadPlan, Plan, ScenarioMode, ScenarioSpec};
use rkc::kernels::{column_batches, full_kernel_matrix, BlockSource, Kernel, NativeBlockSource};
use rkc::linalg::{gemm, gemm_nt, gemm_tn, gemm_with, jacobi_eig, matmul_reference, Mat};
use rkc::lowrank::{
    exact_topr_dense, normalized_frobenius_error, one_pass_recovery, trace_norm_error_psd,
    OnePassSketch,
};
use rkc::rng::{Pcg64, Rng};
use rkc::sketch::{fwht_inplace_with, Srht};

fn random_mat(rng: &mut Pcg64, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

/// Run the full native one-pass pipeline on random data.
fn one_pass(x: &Mat, kernel: Kernel, rank: usize, rp: usize, seed: u64) -> rkc::lowrank::Embedding {
    let mut src = NativeBlockSource::pow2(x.clone(), kernel);
    let (n, np) = (src.n(), src.n_padded());
    let mut rng = Pcg64::seed(seed);
    let mut srht = Srht::draw(&mut rng, np, rp);
    srht.mask_padding(n);
    let mut sk = OnePassSketch::new(srht, n);
    for cols in column_batches(n, 17) {
        let kb = src.block(&cols);
        let rows = sk.srht().apply_to_block(&kb, 1);
        sk.ingest(&cols, &rows);
    }
    one_pass_recovery(&sk, rank)
}

#[test]
fn property_recovery_is_exact_when_rank_covers_spectrum() {
    // quadratic kernel on R^p has rank ≤ p(p+1)/2; with rank ≥ that and
    // enough samples the one-pass recovery is exact to f64 noise
    let mut seeds = Pcg64::seed(1);
    for case in 0..8 {
        let p = 2 + (case % 2); // 2 or 3 -> feature dim 3 or 6
        let feat = p * (p + 1) / 2;
        let n = 40 + 7 * case;
        let mut rng = Pcg64::seed(seeds.next_u64());
        let x = random_mat(&mut rng, p, n);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let emb = one_pass(&x, Kernel::paper_poly2(), feat, feat + 10, 100 + case as u64);
        let err = normalized_frobenius_error(&k, &emb);
        assert!(err < 1e-5, "case {case}: err {err}");
    }
}

#[test]
fn property_theorem1_bounds_hold() {
    // gap = L(Ĉ) − L(C*) ≤ tr(E) ≤ 2‖E‖_* for the best rank-r approx,
    // across datasets / kernels / ranks
    let mut seeds = Pcg64::seed(2);
    for case in 0..6 {
        let n = 50 + 10 * case;
        let k_clusters = 2 + case % 3;
        let mut rng = Pcg64::seed(seeds.next_u64());
        let ds = data::gaussian_blobs(&mut rng, n, 3, k_clusters, 0.5 + 0.1 * case as f64);
        let kernel = if case % 2 == 0 { Kernel::paper_poly2() } else { Kernel::Rbf { gamma: 1.0 } };
        let kmat = full_kernel_matrix(&ds.x, kernel);
        let rank = 1 + case % 3;
        let emb = exact_topr_dense(&kmat, rank);

        let opts = KmeansOpts { k: k_clusters, restarts: 30, max_iters: 100, tol: 1e-12 };
        let mut ra = Pcg64::seed(10 + case as u64);
        let chat = kmeans(&emb.y, &opts, &mut ra);
        let l_chat = kernel_kmeans_objective(&kmat, &chat.labels, k_clusters);
        let mut rb = Pcg64::seed(20 + case as u64);
        let cstar = rkc::clustering::kernel_kmeans(&kmat, k_clusters, 30, 200, &mut rb);
        let l_cstar = cstar.objective.min(l_chat);

        let gap = (l_chat - l_cstar).max(0.0);
        let tr_e = (kmat.trace() - emb.y.frobenius_norm().powi(2)).max(0.0);
        let tn = trace_norm_error_psd(&kmat, &emb);
        let tol = 1e-6 * kmat.trace().max(1.0);
        assert!(gap <= tr_e + tol, "case {case}: gap {gap} > tr(E) {tr_e}");
        assert!(gap <= 2.0 * tn + tol, "case {case}: gap {gap} > 2||E||* {}", 2.0 * tn);
        // Eq. 10 is tighter than Eq. 9 for PSD error: tr(E) ≤ 2‖E‖_*
        assert!(tr_e <= 2.0 * tn + tol);
    }
}

#[test]
fn property_embedding_gram_never_exceeds_kernel_trace() {
    // K̂ = YᵀY from any of our methods satisfies tr(K̂) ≤ tr(K) + noise
    // (eigenvalue clamping can only remove mass for best-rank-r; the
    // one-pass estimate is unbiased so allow slack)
    let mut seeds = Pcg64::seed(3);
    for case in 0..6 {
        let n = 30 + 9 * case;
        let mut rng = Pcg64::seed(seeds.next_u64());
        let x = random_mat(&mut rng, 2, n);
        let k = full_kernel_matrix(&x, Kernel::paper_poly2());
        let emb = exact_topr_dense(&k, 2);
        let tr_hat = emb.y.frobenius_norm().powi(2);
        assert!(tr_hat <= k.trace() * (1.0 + 1e-9), "case {case}");
    }
}

#[test]
fn property_gemm_matches_naive_reference_across_odd_shapes() {
    // every GEMM-backed path reduces to this oracle: C = A·B to ≤1e-12
    // for empty, 1×1, skinny, and non-multiple-of-block shapes, with
    // all three transpose variants and any thread count bit-identical
    let mut rng = Pcg64::seed(40);
    let shapes: &[(usize, usize, usize)] = &[
        (0, 5, 3),
        (5, 0, 3),
        (5, 3, 0),
        (1, 1, 1),
        (2, 7, 1),
        (13, 300, 140), // straddles the KC=256 / NC=128 panel edges
        (33, 257, 129),
        (64, 256, 128),
    ];
    for &(m, k, n) in shapes {
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let want = matmul_reference(&a, &b);
        for threads in [1usize, 3, 8] {
            let got = gemm(&a, &b, threads);
            let diff = got.sub(&want).max_abs();
            assert!(diff <= 1e-12, "gemm {m}x{k}x{n} t={threads}: diff {diff}");
            assert_eq!(got.data(), gemm(&a, &b, 1).data(), "thread bit-identity {m}x{k}x{n}");
        }
        let at = a.transpose();
        let diff_tn = gemm_tn(&at, &b, 2).sub(&want).max_abs();
        assert!(diff_tn <= 1e-12, "gemm_tn {m}x{k}x{n}: diff {diff_tn}");
        let bt = b.transpose();
        let diff_nt = gemm_nt(&a, &bt, 2).sub(&want).max_abs();
        assert!(diff_nt <= 1e-12, "gemm_nt {m}x{k}x{n}: diff {diff_nt}");
    }
}

#[test]
fn property_fwht_qt_omega_equals_explicit_on_padded_and_masked_srht() {
    // the recovery identity across many padded/masked instances: the
    // FWHT-based QᵀΩ over n_real rows must equal the explicit
    // q.t_matmul(Ω) with Q zero-extended to the transform length
    let mut seeds = Pcg64::seed(41);
    for case in 0..8 {
        let n_real = 20 + 11 * case;
        let n = n_real.next_power_of_two();
        let r = 2 + case % 3;
        let rp = (r + 3 + case).min(n);
        let mut rng = Pcg64::seed(seeds.next_u64());
        let mut srht = Srht::draw(&mut rng, n, rp);
        srht.mask_padding(n_real);
        let q = random_mat(&mut rng, n_real, r);
        let q_pad = Mat::from_fn(n, r, |i, j| if i < n_real { q[(i, j)] } else { 0.0 });
        let want = q_pad.t_matmul(&srht.omega());
        let got = rkc::sketch::qt_omega_via_fwht(&srht, &q, 1);
        let scale = want.max_abs().max(1.0);
        let diff = got.sub(&want).max_abs();
        assert!(diff <= 1e-10 * scale, "case {case}: diff {diff} (scale {scale})");
        // and the padded-basis entry point agrees bit-for-bit
        assert_eq!(got.data(), srht.qt_omega(&q_pad).data(), "case {case}");
    }
}

#[test]
fn property_streaming_order_invariance() {
    // ingesting column batches in any order yields the same sketch
    let mut rng = Pcg64::seed(4);
    let x = random_mat(&mut rng, 3, 41);
    let kernel = Kernel::Rbf { gamma: 0.7 };
    let mut srht = Srht::draw(&mut rng, 64, 9);
    srht.mask_padding(41);

    let run = |order: &[Vec<usize>]| {
        let mut src = NativeBlockSource::new(x.clone(), kernel, 64);
        let mut sk = OnePassSketch::new(srht.clone(), 41);
        for cols in order {
            let kb = src.block(cols);
            let rows = sk.srht().apply_to_block(&kb, 1);
            sk.ingest(cols, &rows);
        }
        sk.w().clone()
    };
    let forward = column_batches(41, 8);
    let mut reversed = forward.clone();
    reversed.reverse();
    let a = run(&forward);
    let b = run(&reversed);
    assert_eq!(a.data(), b.data());
}

#[test]
fn property_srht_moments_are_isotropic() {
    // E[Ω Ωᵀ] = r'·I for the SRHT (columns of DHR have entries ±1):
    // empirical second moment over many draws concentrates near that
    let n = 32usize;
    let rp = 4usize;
    let draws = 400;
    let mut acc = Mat::zeros(n, n);
    let mut rng = Pcg64::seed(5);
    for _ in 0..draws {
        let s = Srht::draw(&mut rng, n, rp);
        let om = s.omega();
        acc.add_assign(&om.matmul_t(&om));
    }
    acc.scale(1.0 / draws as f64);
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { rp as f64 } else { 0.0 };
            assert!(
                (acc[(i, j)] - want).abs() < 0.75,
                "second moment at ({i},{j}) = {} want {want}",
                acc[(i, j)]
            );
        }
    }
}

#[test]
fn property_accuracy_bounds_and_symmetry() {
    let mut rng = Pcg64::seed(6);
    for _ in 0..30 {
        let n = 5 + rng.below(60);
        let k = 2 + rng.below(4);
        let a: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.below(k)).collect();
        let acc = accuracy(&a, &b, k);
        assert!((0.0..=1.0).contains(&acc));
        // symmetric in its arguments (best bijection both ways)
        let acc_t = accuracy(&b, &a, k);
        assert!((acc - acc_t).abs() < 1e-12);
        // ARI of identical partitions is 1
        assert!((adjusted_rand_index(&a, &a, k) - 1.0).abs() < 1e-12);
        // accuracy at least the largest class share (map all to majority)
        let mut counts = vec![0usize; k];
        for &x in &b {
            counts[x] += 1;
        }
        let majority = *counts.iter().max().unwrap() as f64 / n as f64;
        assert!(acc <= 1.0 + 1e-12);
        let _ = majority; // accuracy can be below majority for a fixed
                          // predicted partition; only range-checks apply
    }
}

#[test]
fn property_jacobi_eigenvalues_match_trace_and_fro() {
    // Σλ = tr(A), Σλ² = ||A||_F² for symmetric A
    let mut rng = Pcg64::seed(7);
    for case in 0..10 {
        let n = 2 + case;
        let mut a = random_mat(&mut rng, n, n);
        a.symmetrize();
        let (evals, _) = jacobi_eig(&a);
        let tr: f64 = evals.iter().sum();
        let fro2: f64 = evals.iter().map(|l| l * l).sum();
        assert!((tr - a.trace()).abs() < 1e-9 * a.trace().abs().max(1.0));
        assert!((fro2 - a.frobenius_norm().powi(2)).abs() < 1e-8 * fro2.max(1.0));
    }
}

#[test]
fn property_kmeans_objective_monotone_in_k() {
    // more clusters never increases the optimal objective (checked via
    // many restarts)
    let mut rng = Pcg64::seed(8);
    let ds = data::gaussian_blobs(&mut rng, 90, 2, 3, 1.0);
    let mut prev = f64::INFINITY;
    for k in 1..=5 {
        let mut r = Pcg64::seed(100 + k as u64);
        let res = kmeans(
            &ds.x,
            &KmeansOpts { k, restarts: 20, max_iters: 60, tol: 1e-12 },
            &mut r,
        );
        assert!(res.objective <= prev + 1e-6 * prev.max(1.0), "k={k}: {} > {prev}", res.objective);
        prev = res.objective;
    }
}

#[test]
fn property_nystrom_exact_at_full_sampling_any_kernel() {
    let mut seeds = Pcg64::seed(9);
    for case in 0..4 {
        let mut rng = Pcg64::seed(seeds.next_u64());
        let n = 24 + 6 * case;
        let x = random_mat(&mut rng, 2, n);
        let kern = if case % 2 == 0 { Kernel::paper_poly2() } else { Kernel::Linear };
        let k = full_kernel_matrix(&x, kern);
        let (evals, _) = jacobi_eig(&k);
        let true_rank = evals.iter().filter(|&&l| l > 1e-9 * evals[0].max(1e-300)).count();
        let mut src = NativeBlockSource::pow2(x, kern);
        let emb = rkc::lowrank::nystrom(
            &mut src,
            n,
            true_rank,
            rkc::lowrank::NystromSampling::Uniform,
            &mut rng,
        );
        let err = normalized_frobenius_error(&k, &emb);
        assert!(err < 1e-6, "case {case}: err {err} (rank {true_rank})");
    }
}

// ---- experiment-plan properties ------------------------------------

/// Draw a random (but always valid) grid plan: every axis gets 1–3
/// distinct values, scalars stay in-range.
fn random_grid_plan(rng: &mut Pcg64) -> GridPlan {
    let take = |rng: &mut Pcg64, pool: &[&str]| -> Vec<String> {
        let len = 1 + rng.below(pool.len());
        pool[..len].iter().map(|s| s.to_string()).collect()
    };
    let methods = [Method::OnePass, Method::Exact, Method::PlainKmeans, Method::Nystrom { m: 40 }];
    let kernels = [Kernel::paper_poly2(), Kernel::Rbf { gamma: 0.5 }, Kernel::Linear];
    let mut plan = GridPlan::default();
    plan.seed = rng.next_u64();
    plan.datasets = take(rng, &["cross_lines", "gaussian_blobs", "segmentation_like"]);
    plan.ns = (0..1 + rng.below(3)).map(|i| 64 + 32 * i).collect();
    plan.methods = methods[..1 + rng.below(methods.len())].to_vec();
    plan.kernels = kernels[..1 + rng.below(kernels.len())].to_vec();
    plan.ranks = (0..1 + rng.below(2)).map(|i| 2 + i).collect();
    plan.oversamples = (0..1 + rng.below(3)).map(|i| 4 + 2 * i).collect();
    plan.threads = (0..1 + rng.below(2)).map(|i| 1 + i).collect();
    plan.repeats = 1 + rng.below(3);
    plan.timings = rng.below(2) == 0;
    plan
}

/// Draw a random (valid) load plan with 1–3 scenarios.
fn random_load_plan(rng: &mut Pcg64) -> LoadPlan {
    let modes = [
        ScenarioMode::OpenLoop,
        ScenarioMode::Burst,
        ScenarioMode::SlowLoris,
        ScenarioMode::PartialWrite,
    ];
    let mut plan = LoadPlan::default();
    plan.seed = rng.next_u64();
    plan.models = 1 + rng.below(3);
    plan.deadline_ms = 100 * rng.below(5) as u64;
    plan.scenarios = (0..1 + rng.below(3))
        .map(|i| ScenarioSpec {
            name: format!("s{i}"),
            mode: modes[rng.below(modes.len())],
            clients: 1 + rng.below(4),
            requests: 1 + rng.below(4),
            rate_hz: [0.0, 12.5, 50.0][rng.below(3)],
            keep_alive: rng.below(2) == 0,
        })
        .collect();
    plan
}

#[test]
fn property_grid_expansion_count_is_the_axis_product() {
    let mut rng = Pcg64::seed(50);
    for case in 0..40 {
        let plan = random_grid_plan(&mut rng);
        let want = plan.datasets.len()
            * plan.ns.len()
            * plan.methods.len()
            * plan.kernels.len()
            * plan.ranks.len()
            * plan.oversamples.len()
            * plan.threads.len()
            * plan.repeats;
        let trials = expand(&plan);
        assert_eq!(trials.len(), want, "case {case}");
        for (i, t) in trials.iter().enumerate() {
            assert_eq!(t.index, i, "case {case}: indices must be the row order");
        }
    }
}

#[test]
fn property_trial_seeds_are_unique_and_order_independent() {
    let mut rng = Pcg64::seed(51);
    for case in 0..40 {
        let plan = random_grid_plan(&mut rng);
        let trials = expand(&plan);
        // distinct coordinates -> distinct seeds (FNV over the spec
        // string; a collision would silently correlate two trials)
        let seeds: BTreeSet<u64> = trials.iter().map(|t| t.seed).collect();
        assert_eq!(seeds.len(), trials.len(), "case {case}: seed collision");
        // the seed is a pure function of the coordinates...
        for t in &trials {
            let again = trial_seed(
                plan.seed,
                &t.dataset,
                t.n,
                t.method,
                t.kernel,
                t.rank,
                t.oversample,
                t.threads,
                t.repeat,
            );
            assert_eq!(t.seed, again, "case {case}");
        }
        // ...so permuting every axis moves trials but never reseeds them
        let mut permuted = plan.clone();
        permuted.datasets.reverse();
        permuted.ns.reverse();
        permuted.methods.reverse();
        permuted.kernels.reverse();
        permuted.ranks.reverse();
        permuted.oversamples.reverse();
        permuted.threads.reverse();
        let key = |t: &rkc::experiment::Trial| {
            (
                t.dataset.clone(),
                t.n,
                t.method.to_string(),
                t.kernel.to_string(),
                t.rank,
                t.oversample,
                t.threads,
                t.repeat,
            )
        };
        let by_coords: std::collections::BTreeMap<_, _> =
            trials.iter().map(|t| (key(t), t.seed)).collect();
        for t in expand(&permuted) {
            assert_eq!(by_coords[&key(&t)], t.seed, "case {case}");
        }
    }
}

#[test]
fn property_plan_display_reparses_to_an_equal_plan() {
    let mut rng = Pcg64::seed(52);
    for case in 0..40 {
        let plan = if case % 2 == 0 {
            Plan::Grid(random_grid_plan(&mut rng))
        } else {
            Plan::Load(random_load_plan(&mut rng))
        };
        let text = plan.to_string();
        let again = Plan::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(plan, again, "case {case}: round-trip changed the plan");
        assert_eq!(text, again.to_string(), "case {case}: display must be canonical");
    }
}

#[test]
fn property_malformed_plans_are_typed_errors_never_panics() {
    let bad: &[&str] = &[
        "",                                            // missing kind
        "seed 1\n",                                    // missing kind with content
        "kind tournament\n",                           // unknown kind
        "kind grid\nwat 1\n",                          // unknown grid key
        "kind load\nscenario a mode=burst\nwat 1\n",   // unknown load key
        "kind grid\nseed 1\nseed 2\n",                 // duplicate key
        "kind grid\nmethod one_pass,,exact\n",         // empty axis item
        "kind grid\nmethod frobnicate\n",              // bad method
        "kind grid\nkernel poly9000\n",                // bad kernel
        "kind grid\nseed banana\n",                    // non-numeric scalar
        "kind grid\nrank 0\n",                         // rank below 1
        "kind grid\nrepeats 0\n",                      // repeats below 1
        "kind grid\nn 4\n",                            // n below the floor
        "kind grid\nmethod one_pass,one_pass\n",       // duplicate axis value
        "kind grid\njust-one-token\n",                 // no key/value split
        "kind load\n",                                 // load without scenarios
        "kind load\nscenario a clients=2\n",           // scenario missing mode
        "kind load\nscenario mode=burst\n",            // scenario missing name
        "kind load\nscenario a mode=warp\n",           // bad scenario mode
        "kind load\nscenario a mode=burst requests=0\n", // zero requests
        "kind load\nscenario a mode=burst rate=-1\n",  // negative rate
        "kind load\nscenario a mode=burst wat=1\n",    // unknown scenario setting
        "kind load\nscenario a mode=burst\nscenario a mode=burst\n", // duplicate name
        "kind load\nscenario a mode=burst mode=open_loop\n", // duplicate scenario setting
    ];
    for text in bad {
        match Plan::parse(text) {
            Err(RkcError::InvalidConfig(_)) | Err(RkcError::Parse { .. }) => {}
            other => panic!("plan {text:?}: expected a typed parse error, got {other:?}"),
        }
    }
}

// ---- observability histogram properties ----------------------------

/// Linear-scan reference for the bucket an observation must land in:
/// the first bucket whose bound is >= v, else the overflow bucket.
fn reference_bucket(bounds: &[f64], v: f64) -> usize {
    for (i, &b) in bounds.iter().enumerate() {
        if v <= b {
            return i;
        }
    }
    bounds.len()
}

/// A fresh uniquely-labeled histogram series for one property case
/// (registry series are process-global, so reuse would accumulate).
fn fresh_hist(case_label: &str, bounds: &[f64]) -> std::sync::Arc<rkc::obs::Histogram> {
    rkc::obs::registry().histogram(
        "rkc_test_properties_seconds",
        "scratch series for the histogram property tests",
        &[("case", case_label)],
        bounds,
    )
}

#[test]
fn property_histogram_bucketing_matches_linear_scan() {
    let bounds = rkc::obs::latency_buckets();
    let mut rng = Pcg64::seed(60);
    for case in 0..20 {
        let h = fresh_hist(&format!("scan{case}"), bounds);
        let mut want = vec![0u64; bounds.len() + 1];
        let mut sum = 0.0;
        for _ in 0..200 {
            // log-uniform across (and past both ends of) the bound range
            let v = 10f64.powf(-6.0 + 8.0 * rng.next_f64());
            want[reference_bucket(bounds, v)] += 1;
            sum += v;
            h.observe(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.buckets, want, "case {case}");
        assert_eq!(snap.count, 200, "case {case}: count is the bucket sum");
        assert!(
            (snap.sum - sum).abs() <= 1e-9 * sum.abs().max(1.0),
            "case {case}: sum {} want {sum}",
            snap.sum
        );
    }
}

#[test]
fn property_histogram_boundary_values_land_in_their_named_bucket() {
    // Prometheus `le` semantics: v == bound counts *in* that bucket
    let bounds = rkc::obs::size_buckets();
    let h = fresh_hist("boundary", bounds);
    for &b in bounds {
        h.observe(b);
    }
    // strictly past the last bound -> overflow, as does +inf
    h.observe(bounds.last().unwrap() * 2.0);
    h.observe(f64::INFINITY);
    let snap = h.snapshot();
    let (body, overflow) = snap.buckets.split_at(bounds.len());
    assert!(body.iter().all(|&c| c == 1), "one exact hit per named bucket: {body:?}");
    assert_eq!(overflow, &[2], "past-the-end values go to +Inf");
    assert_eq!(snap.count, bounds.len() as u64 + 2);
}

#[test]
fn property_histogram_merge_is_associative_and_checks_bounds() {
    let bounds = rkc::obs::latency_buckets();
    let mut rng = Pcg64::seed(61);
    for case in 0..20 {
        let mut parts = Vec::new();
        for part in 0..3 {
            let h = fresh_hist(&format!("merge{case}_{part}"), bounds);
            for _ in 0..1 + rng.below(50) {
                h.observe(10f64.powf(-6.0 + 8.0 * rng.next_f64()));
            }
            parts.push(h.snapshot());
        }
        // (a + b) + c  ==  a + (b + c): exact on counts, fp-close on sums
        let mut left = parts[0].clone();
        left.merge(&parts[1]).unwrap();
        left.merge(&parts[2]).unwrap();
        let mut bc = parts[1].clone();
        bc.merge(&parts[2]).unwrap();
        let mut right = parts[0].clone();
        right.merge(&bc).unwrap();
        assert_eq!(left.buckets, right.buckets, "case {case}");
        assert_eq!(left.count, right.count, "case {case}");
        assert_eq!(
            left.count,
            parts.iter().map(|p| p.count).sum::<u64>(),
            "case {case}: merge preserves total count"
        );
        assert!(
            (left.sum - right.sum).abs() <= 1e-9 * left.sum.abs().max(1.0),
            "case {case}: sums diverged beyond rounding"
        );
    }
    // bound mismatch is a typed error, not a silent mis-merge
    let a = fresh_hist("mismatch_a", bounds).snapshot();
    let mut b = fresh_hist("mismatch_b", rkc::obs::size_buckets()).snapshot();
    assert!(matches!(b.merge(&a), Err(RkcError::InvalidConfig(_))));
}

#[test]
fn property_histogram_quantiles_are_monotone_upper_bounds() {
    let bounds = rkc::obs::latency_buckets();
    let mut rng = Pcg64::seed(62);
    for case in 0..10 {
        let h = fresh_hist(&format!("quant{case}"), bounds);
        let mut values = Vec::new();
        for _ in 0..120 {
            let v = 10f64.powf(-5.0 + 6.0 * rng.next_f64());
            values.push(v);
            h.observe(v);
        }
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = h.snapshot();
        let mut prev = 0.0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.95, 1.0] {
            let est = snap.quantile(q);
            assert!(est >= prev, "case {case}: quantile must be monotone in q");
            prev = est;
            // upper-bound property: the estimate is >= the true quantile
            // (bucket bounds can only round up, except past the last
            // finite bound where the histogram cannot resolve)
            let idx = ((q * values.len() as f64).ceil() as usize).max(1) - 1;
            let truth = values[idx];
            assert!(
                est >= truth.min(*bounds.last().unwrap()) - 1e-12,
                "case {case}: q={q} est {est} < true {truth}"
            );
        }
    }
    // empty snapshot: quantile is 0 by definition
    assert_eq!(fresh_hist("quant_empty", bounds).snapshot().quantile(0.5), 0.0);
}

#[test]
fn property_every_simd_table_matches_gemm_reference_at_odd_shapes() {
    // the cross-ISA determinism contract: every kernel table this host
    // can run agrees with the naive oracle to ≤1e-12 and with the
    // scalar table to ≤1e-12, at shapes that are not multiples of any
    // lane width (2, 4, 8) and that straddle the packing panels
    let mut rng = Pcg64::seed(70);
    let shapes: &[(usize, usize, usize)] =
        &[(1, 1, 1), (3, 5, 7), (13, 300, 140), (9, 257, 129), (2, 63, 31)];
    for &(m, k, n) in shapes {
        let a = random_mat(&mut rng, m, k);
        let b = random_mat(&mut rng, k, n);
        let want = matmul_reference(&a, &b);
        let scalar = gemm_with(&a, &b, 1, rkc::simd::scalar_table());
        for table in rkc::simd::available_tables() {
            let got = gemm_with(&a, &b, 1, table);
            let isa = table.isa.name();
            let diff = got.sub(&want).max_abs();
            assert!(diff <= 1e-12, "[{isa}] {m}x{k}x{n} vs reference: {diff}");
            let dev = got.sub(&scalar).max_abs();
            assert!(dev <= 1e-12, "[{isa}] {m}x{k}x{n} vs scalar: {dev}");
            // threads=1 ≡ threads=N within the table (per-ISA contract)
            for threads in [3usize, 8] {
                assert_eq!(
                    got.data(),
                    gemm_with(&a, &b, threads, table).data(),
                    "[{isa}] {m}x{k}x{n} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn property_every_simd_table_fwht_is_bit_identical_and_matches_oracle() {
    // the butterfly is elementwise, so SIMD must be *bit*-identical to
    // scalar on every ISA — and both must match the explicit Hadamard
    fn slow_hadamard(x: &[f64]) -> Vec<f64> {
        let n = x.len();
        (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| {
                        let s = if (i & j).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
                        s * x[j]
                    })
                    .sum()
            })
            .collect()
    }
    let mut rng = Pcg64::seed(71);
    for logn in [0usize, 1, 2, 3, 6, 9] {
        let n = 1usize << logn;
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut scalar = x.clone();
        fwht_inplace_with(&mut scalar, rkc::simd::scalar_table());
        let oracle = slow_hadamard(&x);
        for (a, b) in scalar.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-9 * n.max(1) as f64, "scalar vs oracle n={n}");
        }
        for table in rkc::simd::available_tables() {
            let mut got = x.clone();
            fwht_inplace_with(&mut got, table);
            assert_eq!(got, scalar, "n={n} isa={}", table.isa.name());
        }
    }
}

#[test]
fn property_argmin_kernel_is_bit_identical_to_sequential_scan() {
    // the K-means argmin kernel must reproduce the sequential scan
    // exactly on every ISA: same op order (no FMA), strict-< /
    // first-minimum tie-breaking, NaN never winning. Odd k exercises
    // the vector tails; planted ties exercise the cross-lane
    // lexicographic reduction.
    let mut rng = Pcg64::seed(72);
    for k in [1usize, 2, 3, 5, 7, 9, 15, 17, 33] {
        for case in 0..30 {
            let mut g: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let mut cn: Vec<f64> = (0..k).map(|_| rng.normal().abs()).collect();
            let yn = rng.normal().abs();
            if case % 4 == 0 && k > 1 {
                // exact duplicate of the row minimum at the last index:
                // identical (g, cn) operands make the distances
                // bit-identical, so the first occurrence must win on
                // every ISA
                let (mi, _) = (0..k).fold((0, f64::INFINITY), |acc, c| {
                    let d = yn + cn[c] - 2.0 * g[c];
                    if d < acc.1 { (c, d) } else { acc }
                });
                g[k - 1] = g[mi];
                cn[k - 1] = cn[mi];
            }
            if case % 7 == 0 {
                g[case % k] = f64::NAN;
            }
            // sequential reference: the exact pre-SIMD loop
            let mut best = 0usize;
            let mut bestd = f64::INFINITY;
            for (c, &gv) in g.iter().enumerate() {
                let d = yn + cn[c] - 2.0 * gv;
                let d = if d < 0.0 { 0.0 } else { d };
                if d < bestd {
                    bestd = d;
                    best = c;
                }
            }
            for table in rkc::simd::available_tables() {
                let (gi, gd) = (table.argmin_dist2)(&g, yn, &cn);
                let isa = table.isa.name();
                assert_eq!(gi, best, "[{isa}] k={k} case={case}");
                assert!(
                    gd == bestd || (gd.is_nan() && bestd.is_nan()),
                    "[{isa}] k={k} case={case}: {gd} vs {bestd}"
                );
            }
        }
    }
}

#[test]
fn property_f32_serving_path_deviation_is_bounded() {
    // the opt-in f32 embed/predict path must stay within the documented
    // guard of the f64 path on realistic models, and predictions should
    // agree except possibly at cluster boundaries
    let mut seeds = Pcg64::seed(73);
    for case in 0..4 {
        let mut rng = Pcg64::seed(seeds.next_u64());
        let ds = data::gaussian_blobs(&mut rng, 80 + 20 * case, 3, 2 + case % 2, 0.4);
        let kernel = if case % 2 == 0 { Kernel::paper_poly2() } else { Kernel::Rbf { gamma: 0.8 } };
        let model = rkc::api::KernelClusterer::new(2 + case % 2)
            .kernel(kernel)
            .rank(2)
            .oversample(8)
            .seed(17 + case as u64)
            .fit(&ds.x)
            .unwrap();
        let mut qrng = Pcg64::seed(99 + case as u64);
        let query = random_mat(&mut qrng, 3, 16);
        let y64 = model.embed(&query).unwrap();

        let mut m32 = model;
        m32.set_precision(rkc::config::Precision::F32);
        assert_eq!(m32.precision(), rkc::config::Precision::F32);
        let y32 = m32.embed(&query).unwrap();

        // guard: f32 deviation is single-precision-sized relative to
        // the embedding scale, orders of magnitude below the low-rank
        // approximation error the method already accepts
        let scale = y64.max_abs().max(1.0);
        let dev = y32.sub(&y64).max_abs();
        assert!(dev <= 1e-3 * scale, "case {case}: f32 dev {dev} vs scale {scale}");

        // flipping back restores the bit-exact f64 path
        m32.set_precision(rkc::config::Precision::F64);
        assert_eq!(m32.embed(&query).unwrap().data(), y64.data(), "case {case}");
    }
}
