#!/usr/bin/env python3
"""Validate — and optionally baseline-compare — the BENCH_*.json files.

Schema check (always): every file must be a non-empty JSON array of
objects; every object must carry its file's required keys; every numeric
value must be finite (the emitters route timings through
Json::finite_num, which downgrades NaN/inf to null — a raw NaN in the
file means an emitter bypassed it). Exits non-zero on the first
malformed file.

Baseline compare (--baseline PATH): for each checked file that has an
entry in the baseline snapshot, diff the key timing fields of row 0
against the recorded values and print a per-bench delta table (also
appended to $GITHUB_STEP_SUMMARY when set, so it lands in the CI job
summary). Deltas beyond +/-WARN_PCT emit GitHub warning annotations but
NEVER fail the run — CI timings are too noisy to gate on; the table is
the regression trail, the schema is the gate.

Baseline regen (--write-baseline PATH): snapshot the current files' key
timing fields into a fresh baseline (run locally or from a CI artifact
after an intentional perf change).

Usage:
  check_bench_json.py BENCH_foo.json [BENCH_bar.json ...]
  check_bench_json.py --baseline tools/bench_baseline.json BENCH_*.json
  check_bench_json.py --write-baseline tools/bench_baseline.json BENCH_*.json
"""

import json
import math
import os
import sys

# required keys per file (by basename); files not listed here only get
# the generic array/object/finite checks
REQUIRED = {
    "BENCH_pipeline.json": [
        "backend", "threads", "sketch_s", "recovery_s", "kmeans_s",
        "error_pass_s", "total_s", "n", "batch", "iters",
    ],
    "BENCH_recovery.json": [
        "bench", "n", "r", "rp", "threads", "before_s", "after_s", "speedup",
    ],
    "BENCH_kmeans.json": [
        "bench", "n", "r", "k", "restarts", "threads", "before_s",
        "after_s", "speedup",
    ],
    "BENCH_fwht.json": ["bench", "n", "batch", "threads", "median_s"],
    "BENCH_table1.json": ["bench", "method", "trials", "n", "accuracy"],
    "BENCH_fig3.json": ["bench", "series", "m", "accuracy"],
    "BENCH_ablation.json": ["bench"],
    "BENCH_memory.json": [
        "bench", "workload", "method", "persistent_bytes", "ratio_vs_ours",
    ],
    "BENCH_serve.json": [
        "bench", "n_train", "clients", "requests_per_s", "p50_ms", "p95_ms",
        "p99_ms",
    ],
    "BENCH_stream.json": [
        "bench", "scenario", "n_total", "chunk", "refreshes",
        "refresh_p50_ms", "refresh_p95_ms", "acc_stream", "acc_refit",
        "acc_lag",
    ],
}

# the key timing fields the baseline records / compares, per file (row 0
# only — for BENCH_serve.json that is the in_process row). Keep this
# list small and stable: it IS the regression trail's schema.
KEY_TIMINGS = {
    "BENCH_pipeline.json": ["sketch_s", "recovery_s", "kmeans_s", "total_s"],
    "BENCH_recovery.json": ["before_s", "after_s", "speedup"],
    "BENCH_kmeans.json": ["before_s", "after_s", "speedup"],
    "BENCH_fwht.json": ["median_s"],
    "BENCH_table1.json": ["accuracy"],
    "BENCH_fig3.json": ["accuracy"],
    "BENCH_memory.json": ["persistent_bytes"],
    "BENCH_serve.json": ["requests_per_s", "p50_ms", "p95_ms"],
    # row 0 is the moving_blobs scenario
    "BENCH_stream.json": ["refresh_p50_ms", "refresh_p95_ms", "acc_lag"],
}

# baseline entries keyed off *tagged* rows instead of row 0, as
# "<file>#<tag_value>". Each (tag_field, tag_value, keys) triple is a
# schema gate: the tagged row must exist and must carry the listed keys
# on top of the file's REQUIRED set — this is how the obs_overhead
# instrumentation-cost rows, the #simd ISA-dispatch rows, and the
# #f32_path mixed-precision row ride the regression trail.
KEY_TIMINGS_TAGGED = {
    "BENCH_kmeans.json": [("mode", "simd", ["before_s", "after_s", "speedup"])],
    "BENCH_recovery.json": [("mode", "simd", ["before_s", "after_s", "speedup"])],
    "BENCH_serve.json": [
        ("mode", "obs_overhead", ["obs_overhead_pct"]),
        ("mode", "f32_path", ["speedup", "f32_max_abs_dev"]),
    ],
    "BENCH_stream.json": [("scenario", "obs_overhead", ["obs_overhead_pct"])],
}

# warn (never fail) when a compared value drifts beyond this
WARN_PCT = 25.0


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_finite(path, row_idx, key, value):
    if isinstance(value, bool):
        return
    if isinstance(value, (int, float)) and not math.isfinite(value):
        fail(path, f"row {row_idx}: key '{key}' is non-finite ({value!r})")


def check_file(path):
    base = os.path.basename(path)
    try:
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, ValueError) as exc:
        fail(path, f"unreadable or invalid JSON: {exc}")
    if not isinstance(data, list):
        fail(path, f"top level must be a JSON array, got {type(data).__name__}")
    if not data:
        fail(path, "empty record array")
    required = REQUIRED.get(base, [])
    for i, row in enumerate(data):
        if not isinstance(row, dict):
            fail(path, f"row {i} is not an object")
        # a required key serialized as null means a timing went
        # non-finite through Json::finite_num — treat it as missing
        missing = [k for k in required if row.get(k) is None]
        if missing:
            fail(path, f"row {i} missing (or null) required keys {missing}")
        for key, value in row.items():
            check_finite(path, i, key, value)
    for tag_field, tag_value, keys in KEY_TIMINGS_TAGGED.get(base, []):
        tagged = [r for r in data if r.get(tag_field) == tag_value]
        if not tagged:
            fail(path, f"no row with {tag_field}={tag_value!r} (required)")
        missing = [k for k in keys if tagged[0].get(k) is None]
        if missing:
            fail(path, f"{tag_field}={tag_value!r} row missing keys {missing}")
    print(f"ok   {path}: {len(data)} row(s)")
    return data


def snapshot(paths):
    """The baseline view of the given (already-validated) bench files."""
    snap = {}
    for path in paths:
        base = os.path.basename(path)
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
        keys = KEY_TIMINGS.get(base)
        if keys:
            row0 = data[0]
            values = {k: row0[k] for k in keys if isinstance(row0.get(k), (int, float))}
            if values:
                snap[base] = values
        for tag_field, tag_value, tagged_keys in KEY_TIMINGS_TAGGED.get(base, []):
            rows = [r for r in data if r.get(tag_field) == tag_value]
            if rows:
                values = {
                    k: rows[0][k]
                    for k in tagged_keys
                    if isinstance(rows[0].get(k), (int, float))
                }
                if values:
                    snap[f"{base}#{tag_value}"] = values
    return snap


def compare_against_baseline(paths, baseline_path):
    """Print (and append to $GITHUB_STEP_SUMMARY) a per-bench delta
    table; emit ::warning:: annotations beyond +/-WARN_PCT. Never
    fails."""
    try:
        with open(baseline_path, encoding="utf-8") as fh:
            baseline = json.load(fh)
    except (OSError, ValueError) as exc:
        print(f"warn: baseline {baseline_path} unusable ({exc}); skipping compare")
        return
    current = snapshot(paths)
    lines = [
        "## Bench deltas vs committed baseline",
        "",
        f"Baseline: `{baseline_path}` — informational only; drift beyond "
        f"±{WARN_PCT:.0f}% warns, never fails.",
        "",
        "| bench | key | baseline | current | delta |",
        "|---|---|---:|---:|---:|",
    ]
    warnings = []
    for base in sorted(current):
        recorded = baseline.get(base)
        if not isinstance(recorded, dict):
            lines.append(f"| {base} | — | *(not in baseline)* | | |")
            continue
        for key, cur in current[base].items():
            ref = recorded.get(key)
            if not isinstance(ref, (int, float)) or isinstance(ref, bool):
                continue
            if ref == 0:
                delta = "n/a (baseline 0)"
            else:
                pct = (cur - ref) / abs(ref) * 100.0
                flag = " ⚠️" if abs(pct) > WARN_PCT else ""
                delta = f"{pct:+.1f}%{flag}"
                if abs(pct) > WARN_PCT:
                    warnings.append(
                        f"{base}:{key} drifted {pct:+.1f}% vs baseline "
                        f"({ref:g} -> {cur:g})"
                    )
            lines.append(f"| {base} | {key} | {ref:g} | {cur:g} | {delta} |")
    table = "\n".join(lines)
    print(table)
    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a", encoding="utf-8") as fh:
            fh.write(table + "\n")
    for w in warnings:
        # GitHub annotation syntax — visible on the run page, non-fatal
        print(f"::warning title=bench drift::{w}")


def main(argv):
    args = argv[1:]
    baseline = None
    write_baseline = None
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--baseline":
            i += 1
            baseline = args[i] if i < len(args) else fail("args", "--baseline needs a path")
        elif args[i] == "--write-baseline":
            i += 1
            write_baseline = (
                args[i] if i < len(args) else fail("args", "--write-baseline needs a path")
            )
        else:
            paths.append(args[i])
        i += 1
    if not paths:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in paths:
        check_file(path)
    if write_baseline:
        snap = snapshot(paths)
        snap["_note"] = (
            "Quick-mode (RKC_BENCH_QUICK=1) key-timing snapshot. The CI smoke job "
            "regenerates this file on every run (`--write-baseline`) and uploads it as "
            "the `bench-baseline` artifact: to refresh after an intentional perf change, "
            "download that artifact from a green run on main and commit it verbatim, or "
            "run `RKC_BENCH_QUICK=1 cargo bench` locally followed by `python3 "
            "tools/check_bench_json.py --write-baseline tools/bench_baseline.json "
            "BENCH_*.json`. The compare is informational (warn-only) by design, so a "
            "stale entry shows up as a drift warning, never a red build."
        )
        with open(write_baseline, "w", encoding="utf-8") as fh:
            json.dump(snap, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote baseline {write_baseline} ({len(snap) - 1} bench entries)")
    if baseline:
        compare_against_baseline(paths, baseline)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
