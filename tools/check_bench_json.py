#!/usr/bin/env python3
"""Validate the schema of the BENCH_*.json files the benches emit.

Every file must be a non-empty JSON array of objects; every object must
carry its file's required keys; every numeric value must be finite (the
emitters route timings through Json::finite_num, which downgrades
NaN/inf to null — a raw NaN in the file means an emitter bypassed it).

Usage: check_bench_json.py BENCH_foo.json [BENCH_bar.json ...]
Exits non-zero on the first malformed file. Timings are never gated —
this guards the schema so the perf trajectory stays machine-diffable.
"""

import json
import math
import sys

# required keys per file (by basename); files not listed here only get
# the generic array/object/finite checks
REQUIRED = {
    "BENCH_pipeline.json": [
        "backend", "threads", "sketch_s", "recovery_s", "kmeans_s",
        "error_pass_s", "total_s", "n", "batch", "iters",
    ],
    "BENCH_recovery.json": [
        "bench", "n", "r", "rp", "threads", "before_s", "after_s", "speedup",
    ],
    "BENCH_kmeans.json": [
        "bench", "n", "r", "k", "restarts", "threads", "before_s",
        "after_s", "speedup",
    ],
    "BENCH_fwht.json": ["bench", "n", "batch", "threads", "median_s"],
    "BENCH_table1.json": ["bench", "method", "trials", "n", "accuracy"],
    "BENCH_fig3.json": ["bench", "series", "m", "accuracy"],
    "BENCH_ablation.json": ["bench"],
    "BENCH_memory.json": [
        "bench", "workload", "method", "persistent_bytes", "ratio_vs_ours",
    ],
    "BENCH_serve.json": [
        "bench", "n_train", "clients", "requests_per_s", "p50_ms", "p95_ms",
        "p99_ms",
    ],
}


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def check_finite(path, row_idx, key, value):
    if isinstance(value, bool):
        return
    if isinstance(value, (int, float)) and not math.isfinite(value):
        fail(path, f"row {row_idx}: key '{key}' is non-finite ({value!r})")


def check_file(path):
    base = path.rsplit("/", 1)[-1]
    try:
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, ValueError) as exc:
        fail(path, f"unreadable or invalid JSON: {exc}")
    if not isinstance(data, list):
        fail(path, f"top level must be a JSON array, got {type(data).__name__}")
    if not data:
        fail(path, "empty record array")
    required = REQUIRED.get(base, [])
    for i, row in enumerate(data):
        if not isinstance(row, dict):
            fail(path, f"row {i} is not an object")
        # a required key serialized as null means a timing went
        # non-finite through Json::finite_num — treat it as missing
        missing = [k for k in required if row.get(k) is None]
        if missing:
            fail(path, f"row {i} missing (or null) required keys {missing}")
        for key, value in row.items():
            check_finite(path, i, key, value)
    print(f"ok   {path}: {len(data)} row(s)")


def main(argv):
    if len(argv) < 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in argv[1:]:
        check_file(path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
