#!/usr/bin/env python3
"""Validate a `GET /metrics` scrape (Prometheus text exposition 0.0.4).

Checks, per file:
  - every non-comment line parses as `name[{labels}] value`
  - metric and label names match the Prometheus grammar; label values
    are double-quoted with only `\\\\`, `\\"`, and `\\n` escapes
  - every sample's family carries `# HELP` and `# TYPE` lines *before*
    its first sample, with a known type
  - sample values parse as floats; counter/histogram values are finite
    and non-negative
  - histograms: per series (labels minus `le`), the `_bucket` counts
    are cumulative non-decreasing, the last bucket is `le="+Inf"`, its
    count equals the series' `_count`, and `_sum` exists

Across two files (scrape-before, scrape-after):
  - every counter / `_count` / `_bucket` series present in both must be
    monotone non-decreasing (counters never go backwards)
  - `--expect-grew NAME` (repeatable): the summed value of that sample
    name must be strictly larger in the second file
  - `--require NAME` (repeatable): the family must exist in the last
    file given (use for coverage: serve, stream, and pipeline series)

Usage:
  check_metrics_text.py [--require NAME]... [--expect-grew NAME]... \
      before.txt [after.txt]

Exits non-zero on the first violation.
"""

import math
import re
import sys

METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_NAME = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
KNOWN_TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def parse_labels(path, lineno, text):
    """Parse the `a="b",c="d"` body of a label set (braces stripped)."""
    labels = {}
    i = 0
    while i < len(text):
        m = re.match(r"[a-zA-Z_][a-zA-Z0-9_]*", text[i:])
        if not m:
            fail(path, f"line {lineno}: bad label name at ...{text[i:]!r}")
        name = m.group(0)
        i += len(name)
        if not text[i : i + 2] == '="':
            fail(path, f"line {lineno}: label {name} missing '=\"'")
        i += 2
        value = []
        while i < len(text):
            ch = text[i]
            if ch == "\\":
                if i + 1 >= len(text) or text[i + 1] not in '\\"n':
                    fail(path, f"line {lineno}: bad escape in label {name}")
                value.append({"\\": "\\", '"': '"', "n": "\n"}[text[i + 1]])
                i += 2
            elif ch == '"':
                i += 1
                break
            else:
                value.append(ch)
                i += 1
        else:
            fail(path, f"line {lineno}: unterminated value for label {name}")
        if name in labels:
            fail(path, f"line {lineno}: duplicate label {name}")
        labels[name] = "".join(value)
        if i < len(text):
            if text[i] != ",":
                fail(path, f"line {lineno}: expected ',' between labels")
            i += 1
    return labels


def base_family(name):
    """The family a sample belongs to (strips histogram suffixes)."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def parse_file(path):
    """Return (families, samples).

    families: name -> {"help": bool, "type": str, declared_line: int}
    samples:  list of (lineno, name, labels-dict, value)
    """
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except OSError as exc:
        fail(path, f"unreadable: {exc}")
    families = {}
    samples = []
    for lineno, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            kind_of_comment = line[2:6]
            rest = line[7:]
            parts = rest.split(" ", 1)
            name = parts[0]
            if not METRIC_NAME.match(name):
                fail(path, f"line {lineno}: bad metric name {name!r}")
            fam = families.setdefault(name, {"help": False, "type": None})
            if kind_of_comment == "HELP":
                if len(parts) < 2 or not parts[1].strip():
                    fail(path, f"line {lineno}: HELP for {name} has no text")
                fam["help"] = True
            else:
                if len(parts) < 2 or parts[1] not in KNOWN_TYPES:
                    fail(path, f"line {lineno}: TYPE for {name} is not one of {sorted(KNOWN_TYPES)}")
                fam["type"] = parts[1]
            continue
        if line.startswith("#"):
            continue  # free-form comment: legal, ignored

        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})? (\S+)$", line)
        if not m:
            fail(path, f"line {lineno}: unparseable sample line {line!r}")
        name, _, label_text, value_text = m.groups()
        labels = parse_labels(path, lineno, label_text) if label_text else {}
        try:
            value = float(value_text)
        except ValueError:
            fail(path, f"line {lineno}: value {value_text!r} is not a float")

        family = base_family(name)
        fam = families.get(family) or families.get(name)
        if fam is None:
            fail(path, f"line {lineno}: sample {name} has no # TYPE declaration")
        if not fam["help"] or fam["type"] is None:
            fail(path, f"line {lineno}: family of {name} is missing HELP or TYPE")
        if fam["type"] in ("counter", "histogram"):
            if not math.isfinite(value) or value < 0:
                fail(path, f"line {lineno}: {name} = {value} (counters must be finite, >= 0)")
        samples.append((lineno, name, labels, value))
    return families, samples


def series_key(name, labels, drop=()):
    items = tuple(sorted((k, v) for k, v in labels.items() if k not in drop))
    return (name, items)


def check_histograms(path, families, samples):
    """Cumulative-bucket and _count/_sum coherence per histogram series."""
    buckets = {}  # (family, labels-minus-le) -> list of (le, value, lineno)
    counts = {}
    sums = {}
    for lineno, name, labels, value in samples:
        family = base_family(name)
        if families.get(family, {}).get("type") != "histogram":
            continue
        if name == family + "_bucket":
            if "le" not in labels:
                fail(path, f"line {lineno}: {name} sample without an le label")
            key = series_key(family, labels, drop=("le",))
            buckets.setdefault(key, []).append((labels["le"], value, lineno))
        elif name == family + "_count":
            counts[series_key(family, labels)] = value
        elif name == family + "_sum":
            sums[series_key(family, labels)] = value
        elif name == family:
            fail(path, f"line {lineno}: bare sample {name} for a histogram family")

    if not buckets and any(f.get("type") == "histogram" for f in families.values()):
        fail(path, "histogram TYPE declared but no _bucket samples found")
    for (family, labels), entries in buckets.items():
        where = f"histogram {family}{dict(labels)}"
        if entries[-1][0] != "+Inf":
            fail(path, f"{where}: last bucket is le={entries[-1][0]!r}, want +Inf")
        prev_le, prev_v = None, -1.0
        for le_text, value, lineno in entries:
            le = math.inf if le_text == "+Inf" else float(le_text)
            if prev_le is not None and not le > prev_le:
                fail(path, f"{where}: le bounds not increasing at line {lineno}")
            if value < prev_v:
                fail(path, f"{where}: cumulative count decreased at le={le_text}")
            prev_le, prev_v = le, value
        key = (family, labels)
        if key not in counts:
            fail(path, f"{where}: missing _count")
        if key not in sums:
            fail(path, f"{where}: missing _sum")
        if counts[key] != entries[-1][1]:
            fail(path, f"{where}: _count {counts[key]} != +Inf bucket {entries[-1][1]}")


def monotone_series(path_a, path_b, fams_a, samples_a, fams_b, samples_b):
    """Counter-ish series shared by both scrapes must never decrease."""

    def counterish(samples, families):
        out = {}
        for _, name, labels, value in samples:
            family = base_family(name)
            ftype = families.get(family, {}).get("type")
            if ftype == "counter" or (
                ftype == "histogram" and name != family + "_sum"
            ):
                out[series_key(name, labels)] = value
        return out

    before = counterish(samples_a, fams_a)
    after = counterish(samples_b, fams_b)
    shared = sorted(set(before) & set(after))
    for key in shared:
        if after[key] < before[key]:
            name, labels = key
            fail(
                path_b,
                f"counter {name}{dict(labels)} went backwards: "
                f"{before[key]} -> {after[key]} (vs {path_a})",
            )
    return len(shared)


def main(argv):
    args = argv[1:]
    required, expect_grew, paths = [], [], []
    i = 0
    while i < len(args):
        if args[i] == "--require":
            i += 1
            required.append(args[i])
        elif args[i] == "--expect-grew":
            i += 1
            expect_grew.append(args[i])
        else:
            paths.append(args[i])
        i += 1
    if not paths or len(paths) > 2:
        print(__doc__.strip(), file=sys.stderr)
        return 2

    parsed = []
    for path in paths:
        families, samples = parse_file(path)
        if not samples:
            fail(path, "no samples at all")
        check_histograms(path, families, samples)
        parsed.append((path, families, samples))
        print(f"ok   {path}: {len(families)} families, {len(samples)} samples")

    last_path, last_families, last_samples = parsed[-1]
    for name in required:
        if name not in last_families:
            fail(last_path, f"required family {name!r} is absent")
        if not any(base_family(s[1]) == name for s in last_samples):
            fail(last_path, f"required family {name!r} has no samples")
    if required:
        print(f"ok   {last_path}: all {len(required)} required families present")

    if len(parsed) == 2:
        (pa, fa, sa), (pb, fb, sb) = parsed
        shared = monotone_series(pa, pb, fa, sa, fb, sb)
        print(f"ok   {pb}: {shared} shared counter series monotone vs {pa}")
        for name in expect_grew:
            total_a = sum(v for _, n, _, v in sa if n == name)
            total_b = sum(v for _, n, _, v in sb if n == name)
            if not total_b > total_a:
                fail(pb, f"{name} did not grow: {total_a} -> {total_b}")
            print(f"ok   {pb}: {name} grew {total_a} -> {total_b}")
    elif expect_grew:
        fail(paths[0], "--expect-grew needs two files (before, after)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
