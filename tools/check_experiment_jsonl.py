#!/usr/bin/env python3
"""Validate the JSONL files `rkc experiment` emits.

Every file must open with a header row binding it to the exact plan
that produced it: `row:"header"`, `kind` (grid|load), `plan_hash`
(16-hex FNV-1a 64 of the plan text — recomputed here when --plan is
given), `schema` (this script understands schema 1), `rows` (the data
row count, cross-checked), and `timings` (grid: whether per-stage
wall-time keys are present).

Grid data rows must carry every trial coordinate and metric key;
`approx_error` is the one key allowed to be null (plain_kmeans has no
kernel approximation). Load (scenario) rows must carry the traffic
shape, outcome counters, front-end deltas, and the latency percentiles
— null percentiles are only legal when the scenario saw no 2xx at all.
All numerics must be finite (the emitters route metrics through
Json::finite_num, which downgrades NaN/inf to null — a raw NaN means an
emitter bypassed it). Exits non-zero on the first malformed file.

Usage:
  check_experiment_jsonl.py results.jsonl [more.jsonl ...]
  check_experiment_jsonl.py --plan plans/smoke.plan exp_smoke.jsonl
"""

import json
import math
import sys

SCHEMA = 1

HEADER_KEYS = ["row", "kind", "plan_hash", "schema", "rows", "timings"]

GRID_KEYS = [
    "row", "trial", "repeat", "dataset", "n", "k", "method", "kernel",
    "rank", "oversample", "threads", "batch", "seed", "accuracy", "ari",
    "nmi", "objective", "peak_bytes", "persistent_bytes",
]
GRID_TIMING_KEYS = ["sketch_s", "recovery_s", "kmeans_s", "error_s"]
# plain_kmeans has no kernel approximation: the key must exist, null OK
GRID_NULLABLE = ["approx_error"]

LOAD_KEYS = [
    "row", "scenario", "mode", "clients", "requests_per_client",
    "rate_hz", "keep_alive", "sent", "ok", "dropped", "http_408",
    "http_503", "wall_s", "fe_connections", "fe_requests",
    "fe_failures", "fe_shed",
]
# latency stats of an empty latency set are legitimately null
LOAD_PERCENTILES = ["p50_ms", "p95_ms", "p99_ms", "mean_ms"]


def fail(path, msg):
    print(f"FAIL {path}: {msg}", file=sys.stderr)
    sys.exit(1)


def fnv1a64(data):
    """FNV-1a 64 — must match rust/src/model_io checksum()."""
    h = 0xCBF29CE484222325
    for byte in data:
        h = ((h ^ byte) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def check_finite(path, lineno, key, value):
    if isinstance(value, bool):
        return
    if isinstance(value, (int, float)) and not math.isfinite(value):
        fail(path, f"line {lineno}: key '{key}' is non-finite ({value!r})")


def require(path, lineno, row, keys, nullable=()):
    missing = [k for k in keys if k not in row or (k not in nullable and row[k] is None)]
    if missing:
        fail(path, f"line {lineno}: missing (or null) required keys {missing}")
    for key, value in row.items():
        check_finite(path, lineno, key, value)


def check_file(path, plan_hash):
    try:
        with open(path, encoding="utf-8") as fh:
            lines = fh.read().splitlines()
    except OSError as exc:
        fail(path, f"unreadable: {exc}")
    if not lines:
        fail(path, "empty file")
    rows = []
    for lineno, line in enumerate(lines, start=1):
        try:
            row = json.loads(line)
        except ValueError as exc:
            fail(path, f"line {lineno}: invalid JSON: {exc}")
        if not isinstance(row, dict):
            fail(path, f"line {lineno}: not a JSON object")
        rows.append(row)

    header = rows[0]
    require(path, 1, header, HEADER_KEYS)
    if header["row"] != "header":
        fail(path, f"first line must be the header row, got row={header['row']!r}")
    if header["schema"] != SCHEMA:
        fail(path, f"schema {header['schema']!r} (this validator understands {SCHEMA})")
    kind = header["kind"]
    if kind not in ("grid", "load"):
        fail(path, f"unknown kind {kind!r}")
    data = rows[1:]
    if header["rows"] != len(data):
        fail(path, f"header claims {header['rows']} rows, file has {len(data)}")
    if not data:
        fail(path, "no data rows after the header")
    if plan_hash is not None and header["plan_hash"] != plan_hash:
        fail(
            path,
            f"plan_hash {header['plan_hash']} does not match the plan file ({plan_hash})",
        )

    if kind == "grid":
        keys = GRID_KEYS + (GRID_TIMING_KEYS if header["timings"] else [])
        for lineno, row in enumerate(data, start=2):
            require(path, lineno, row, keys, nullable=GRID_NULLABLE)
            if row["row"] != "trial":
                fail(path, f"line {lineno}: grid data rows must have row='trial'")
            if not header["timings"]:
                present = [k for k in GRID_TIMING_KEYS if k in row]
                if present:
                    fail(path, f"line {lineno}: timings=false but found {present}")
    else:
        for lineno, row in enumerate(data, start=2):
            require(path, lineno, row, LOAD_KEYS)
            if row["row"] != "scenario":
                fail(path, f"line {lineno}: load data rows must have row='scenario'")
            for key in LOAD_PERCENTILES:
                if key not in row:
                    fail(path, f"line {lineno}: missing percentile key '{key}'")
                if row[key] is None and row["ok"] > 0:
                    fail(path, f"line {lineno}: '{key}' is null but ok={row['ok']}")
    print(f"ok   {path}: header + {len(data)} {kind} row(s)")


def main(argv):
    args = argv[1:]
    plan_hash = None
    paths = []
    i = 0
    while i < len(args):
        if args[i] == "--plan":
            i += 1
            if i >= len(args):
                fail("args", "--plan needs a path")
            with open(args[i], "rb") as fh:
                plan_hash = f"{fnv1a64(fh.read()):016x}"
        else:
            paths.append(args[i])
        i += 1
    if not paths:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    for path in paths:
        check_file(path, plan_hash)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
