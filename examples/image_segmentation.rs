//! END-TO-END DRIVER — the Fig. 3 workload on the full production stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example image_segmentation -- \
//!     [--trials 20] [--backend xla|native] [--sweep 10,20,50,100]
//! ```
//!
//! This is the system's flagship run: the segmentation dataset (real UCI
//! file at data/segmentation.csv if present, else the documented
//! synthetic substitute: n = 2310, p = 19, K = 7, unit-ℓ2 rows,
//! homogeneous quadratic kernel), streamed through the XLA artifacts
//! (Pallas gram kernel + Pallas FWHT, PJRT CPU client) by the rust
//! coordinator, with the full method comparison of Fig. 3(a)/(b) and the
//! paper's headline memory ratio. Results land in results/ and are
//! recorded in EXPERIMENTS.md.

use std::time::Instant;

use rkc::config::{Backend, Cli, ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::{MemoryModel, Table};
use rkc::runtime::ArtifactRegistry;

fn main() -> rkc::error::Result<()> {
    let cli = Cli::parse(std::env::args().skip(1), &[])?;
    let mut cfg = ExperimentConfig::default(); // Fig. 3 protocol
    cfg.trials = cli.get_usize("trials")?.unwrap_or(20);
    if let Some(b) = cli.get("backend") {
        cfg.set("backend", b)?;
    } else {
        cfg.backend = Backend::Xla; // production path by default
    }
    if let Some(d) = cli.get("data_dir") {
        cfg.set("data_dir", d)?;
    }
    let registry = match cfg.backend {
        Backend::Xla => Some(ArtifactRegistry::open(&cfg.artifacts_dir)?),
        Backend::Native => None,
    };
    let sweep: Vec<usize> = cli
        .get("sweep")
        .unwrap_or("10,20,30,50,70,100")
        .split(',')
        .map(|s| s.parse().expect("--sweep takes comma-separated ints"))
        .collect();

    let t0 = Instant::now();
    let ds = build_dataset(&cfg)?;
    println!(
        "workload: {} | kernel {} | r={} l={} (r'={}) | backend {:?} | trials {}",
        ds.name,
        cfg.kernel.describe(),
        cfg.rank,
        cfg.oversample,
        cfg.sketch_width(),
        cfg.backend,
        cfg.trials
    );
    if let Some(reg) = &registry {
        println!("pjrt platform: {}", reg.platform());
    }

    // ---- reference methods ----
    let mut table = Table::new(
        "Fig. 3 — image segmentation workload",
        &["method", "m", "approx err", "accuracy", "nmi", "peak MiB", "time_s"],
    );
    let mut push = |agg: &rkc::coordinator::TrialAggregate, m: &str| {
        table.row(vec![
            agg.method.clone(),
            m.to_string(),
            if agg.error_mean.is_nan() { "–".into() } else { format!("{:.3}", agg.error_mean) },
            format!("{:.3}", agg.accuracy_mean),
            format!("{:.3}", agg.nmi_mean),
            format!("{:.2}", agg.peak_memory_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.1}", agg.total_time.as_secs_f64()),
        ]);
    };

    for method in [Method::Exact, Method::OnePass, Method::FullKernel, Method::PlainKmeans] {
        let mut c = cfg.clone();
        c.method = method;
        if method == Method::FullKernel {
            c.trials = 1;
        }
        let agg = run_trials(&c, &ds, registry.as_ref())?;
        eprintln!("  {} done ({:.1}s)", agg.method, agg.total_time.as_secs_f64());
        push(&agg, "–");
    }

    // ---- the Nyström m-sweep (Fig. 3 x-axis) ----
    let mut csv_rows = Vec::new();
    for &m in &sweep {
        let mut c = cfg.clone();
        c.method = Method::Nystrom { m };
        let agg = run_trials(&c, &ds, registry.as_ref())?;
        eprintln!("  nystrom m={m} done ({:.1}s)", agg.total_time.as_secs_f64());
        csv_rows.push(vec![m as f64, agg.error_mean, agg.accuracy_mean]);
        push(&agg, &m.to_string());
    }
    print!("{}", table.render());

    // ---- headline metric: memory at matched accuracy ----
    let n_pad = ds.n().next_power_of_two();
    let ours_mem = MemoryModel::one_pass(ds.n(), n_pad, cfg.sketch_width(), cfg.rank, cfg.batch);
    let nys50 = MemoryModel::nystrom(ds.n(), 50, cfg.rank);
    println!(
        "\nheadline: ours r'={} persistent {:.2} MiB vs Nyström m=50 {:.2} MiB → {:.1}× lower memory \
         (paper claims ≈10× at matched accuracy; m≈7·r' crossover)",
        cfg.sketch_width(),
        ours_mem.persistent as f64 / (1024.0 * 1024.0),
        nys50.persistent as f64 / (1024.0 * 1024.0),
        nys50.persistent as f64 / ours_mem.persistent as f64,
    );

    std::fs::create_dir_all("results")?;
    rkc::metrics::write_csv(
        "results/image_segmentation_sweep.csv",
        &["m", "approx_error", "accuracy"],
        &csv_rows,
    )?;
    println!("wrote results/image_segmentation_sweep.csv | total {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
