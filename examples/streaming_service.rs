//! The coordinator as a long-running clustering service.
//!
//! ```bash
//! cargo run --release --example streaming_service -- [--requests 8] [--xla]
//! ```
//!
//! Demonstrates the L3 system character beyond one-shot experiments: a
//! request loop receives clustering jobs (dataset + kernel + K), pushes
//! each through the streaming sketch pipeline with bounded-channel
//! backpressure, and reports per-request latency percentiles and
//! sustained throughput — the operational shape of a deployment, where
//! the XLA artifacts are compiled once and reused across requests.

use std::time::Instant;

use rkc::config::{Backend, Cli, ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_experiment};
use rkc::runtime::ArtifactRegistry;
use rkc::util::percentile;

fn main() -> anyhow::Result<()> {
    let cli = Cli::parse(std::env::args().skip(1), &["xla"]).map_err(anyhow::Error::msg)?;
    let requests = cli.get_usize("requests").map_err(anyhow::Error::msg)?.unwrap_or(8);
    let use_xla = cli.has_flag("xla");
    let registry = if use_xla { Some(ArtifactRegistry::open("artifacts")?) } else { None };

    // a mixed job queue: alternating workloads, like a real service
    let jobs: Vec<ExperimentConfig> = (0..requests)
        .map(|i| {
            let mut cfg = ExperimentConfig::default();
            cfg.backend = if use_xla { Backend::Xla } else { Backend::Native };
            cfg.method = Method::OnePass;
            cfg.trials = 1;
            cfg.seed = 1000 + i as u64;
            match i % 3 {
                0 => {
                    cfg.dataset = "cross_lines".into();
                    cfg.n = 1024;
                    cfg.p = 2;
                    cfg.k = 2;
                    cfg.oversample = 10;
                }
                1 => {
                    cfg.dataset = "segmentation_like".into();
                    cfg.n = 1155;
                    cfg.p = 19;
                    cfg.k = 7;
                }
                _ => {
                    cfg.dataset = "blobs".into();
                    cfg.n = 900;
                    cfg.p = 8;
                    cfg.k = 4;
                }
            }
            cfg
        })
        .collect();

    println!("service up: backend={} queue={requests} jobs", if use_xla { "xla" } else { "native" });
    let t_service = Instant::now();
    let mut latencies = Vec::new();
    for (i, cfg) in jobs.iter().enumerate() {
        let t0 = Instant::now();
        let ds = build_dataset(cfg)?;
        let out = run_experiment(cfg, &ds, registry.as_ref(), cfg.seed)?;
        let lat = t0.elapsed().as_secs_f64();
        latencies.push(lat);
        println!(
            "  req {i:2}: {:24} n={:5} acc={:.3} err={:.3} latency={:.3}s (sketch {:.3}s, kmeans {:.3}s)",
            ds.name,
            ds.n(),
            out.accuracy,
            out.approx_error,
            lat,
            out.sketch_time.as_secs_f64(),
            out.kmeans_time.as_secs_f64(),
        );
    }
    let total = t_service.elapsed().as_secs_f64();
    println!(
        "served {requests} requests in {total:.2}s  |  p50 {:.3}s  p95 {:.3}s  throughput {:.2} req/s",
        percentile(&latencies, 50.0),
        percentile(&latencies, 95.0),
        requests as f64 / total,
    );
    Ok(())
}
