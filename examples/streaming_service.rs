//! The library API as a long-running clustering service.
//!
//! ```bash
//! cargo run --release --example streaming_service -- [--requests 8] [--xla]
//! ```
//!
//! Demonstrates the system character beyond one-shot experiments: a
//! request loop receives clustering jobs (dataset + kernel + K), builds a
//! `KernelClusterer` per job, and reports per-request latency percentiles
//! and sustained throughput — the operational shape of a deployment. With
//! `--xla` the artifact registry is opened once and shared across every
//! request (artifacts compile lazily on first use and are reused after).

use std::time::Instant;

use rkc::api::KernelClusterer;
use rkc::clustering::accuracy;
use rkc::config::{Backend, Cli};
use rkc::data::{self, Dataset};
use rkc::kernels::Kernel;
use rkc::rng::Pcg64;
use rkc::runtime::ArtifactRegistry;
use rkc::util::percentile;

fn main() -> rkc::error::Result<()> {
    let cli = Cli::parse(std::env::args().skip(1), &["xla"])?;
    let requests = cli.get_usize("requests")?.unwrap_or(8);
    let use_xla = cli.has_flag("xla");
    let backend = if use_xla { Backend::Xla } else { Backend::Native };
    // compiled once, reused across requests
    let registry = if use_xla { Some(ArtifactRegistry::open("artifacts")?) } else { None };

    // a mixed job queue: alternating workloads, like a real service
    let jobs: Vec<(Dataset, KernelClusterer)> = (0..requests)
        .map(|i| {
            let seed = 1000 + i as u64;
            let mut rng = Pcg64::seed_stream(seed, 0xda7a);
            let (ds, clusterer) = match i % 3 {
                0 => (
                    data::cross_lines(&mut rng, 1024),
                    KernelClusterer::new(2).oversample(10),
                ),
                1 => (
                    data::segmentation_like(&mut rng, 1155, 19, 7),
                    KernelClusterer::new(7),
                ),
                _ => (
                    data::gaussian_blobs(&mut rng, 900, 8, 4, 0.6),
                    KernelClusterer::new(4).kernel(Kernel::Rbf { gamma: 0.5 }),
                ),
            };
            (ds, clusterer.backend(backend).seed(seed))
        })
        .collect();

    println!(
        "service up: backend={} queue={requests} jobs",
        if use_xla { "xla" } else { "native" }
    );
    let t_service = Instant::now();
    let mut latencies = Vec::new();
    for (i, (ds, clusterer)) in jobs.iter().enumerate() {
        let t0 = Instant::now();
        let model = clusterer.fit_with_registry(&ds.x, registry.as_ref())?;
        let err = model.approx_error()?;
        let lat = t0.elapsed().as_secs_f64();
        latencies.push(lat);
        println!(
            "  req {i:2}: {:28} n={:5} acc={:.3} err={err:.3} latency={lat:.3}s (sketch {:.3}s, kmeans {:.3}s)",
            ds.name,
            ds.n(),
            accuracy(model.labels(), &ds.labels, ds.k),
            model.metrics().sketch_time.as_secs_f64(),
            model.metrics().kmeans_time.as_secs_f64(),
        );
    }
    let total = t_service.elapsed().as_secs_f64();
    println!(
        "served {requests} requests in {total:.2}s  |  p50 {:.3}s  p95 {:.3}s  throughput {:.2} req/s",
        percentile(&latencies, 50.0),
        percentile(&latencies, 95.0),
        requests as f64 / total,
    );
    Ok(())
}
