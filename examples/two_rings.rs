//! Fig. 1 / Fig. 2 / Table 1 scenario on the synthetic workload.
//!
//! ```bash
//! cargo run --release --example two_rings -- [--n 4000] [--trials 20] [--xla]
//! ```
//!
//! Reproduces the paper's synthetic experiment end to end:
//!   1. Fig. 1 — plain K-means centroids are useless on the data
//!      (dumped to results/fig1_*.csv for plotting);
//!   2. Fig. 2 — the rank-2 embeddings from (a) exact EVD and (b) our
//!      one-pass method both separate the clusters (fig2*.csv);
//!   3. Table 1 — kernel approximation error + clustering accuracy for
//!      exact / ours / Nyström m=20 / m=100.
//!
//! (Named two_rings after the classic figure; the generator is the
//! crossing-lines set that actually reproduces Table 1 — see DESIGN.md.)

use rkc::config::{Backend, Cli, ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};
use rkc::metrics::Table;
use rkc::runtime::ArtifactRegistry;

fn main() -> rkc::error::Result<()> {
    let cli = Cli::parse(std::env::args().skip(1), &["xla"])?;
    let mut cfg = ExperimentConfig::table1();
    cfg.n = cli.get_usize("n")?.unwrap_or(4000);
    cfg.trials = cli.get_usize("trials")?.unwrap_or(20);
    let registry = if cli.has_flag("xla") {
        cfg.backend = Backend::Xla;
        Some(ArtifactRegistry::open(&cfg.artifacts_dir)?)
    } else {
        None
    };
    let ds = build_dataset(&cfg)?;
    std::fs::create_dir_all("results")?;

    // ---- Fig. 1: plain K-means centroids on the raw data ----
    let mut rng = rkc::rng::Pcg64::seed(cfg.seed);
    let km = rkc::clustering::kmeans(&ds.x, &rkc::clustering::KmeansOpts::paper(2), &mut rng);
    rkc::data::write_points_csv("results/fig1_data.csv", &ds.x, &ds.labels)?;
    rkc::data::write_points_csv("results/fig1_centroids.csv", &km.centroids, &[0, 1])?;
    let acc_plain = rkc::clustering::accuracy(&km.labels, &ds.labels, 2);
    println!("Fig 1: plain K-means accuracy = {acc_plain:.2} (paper: 0.53) — centroids dumped");

    // ---- Table 1 ----
    let mut table = Table::new(
        "Table 1 (paper: exact 0.40/0.99, ours 0.40/0.99, nys20 0.56/0.74, nys100 0.44/0.75)",
        &["method", "kernel approx err", "clustering acc"],
    );
    for method in [
        Method::Exact,
        Method::OnePass,
        Method::Nystrom { m: 20 },
        Method::Nystrom { m: 100 },
    ] {
        let mut c = cfg.clone();
        c.method = method;
        let agg = run_trials(&c, &ds, registry.as_ref())?;
        table.row(vec![
            agg.method.clone(),
            format!("{:.2}", agg.error_mean),
            format!("{:.2}", agg.accuracy_mean),
        ]);
        eprintln!("  {} ({:.1}s)", agg.method, agg.total_time.as_secs_f64());
    }
    print!("{}", table.render());

    // ---- Fig. 2: embeddings (streaming exact — O(rn) memory even here) ----
    let mut src = rkc::kernels::NativeBlockSource::pow2(ds.x.clone(), cfg.kernel);
    let exact = rkc::lowrank::exact_topr_streaming(&mut src, cfg.rank, 40, cfg.batch);
    rkc::data::write_points_csv("results/fig2a_exact.csv", &exact.y, &ds.labels)?;
    println!(
        "Fig 2a: exact embedding dumped (err={:.3})",
        rkc::lowrank::streamed_frobenius_error(&mut src, &exact, cfg.batch)
    );
    Ok(())
}
