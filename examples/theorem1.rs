//! Empirical validation of Theorem 1.
//!
//! ```bash
//! cargo run --release --example theorem1
//! ```
//!
//! For a range of datasets, kernels, and ranks, computes
//!   gap = L(Ĉ) − L(C*)
//! where Ĉ optimizes kernel K-means under the rank-r approximation
//! K̂ = YᵀY and C* under the true K (both located by heavy multi-restart
//! search — the theorem speaks about optima, so we also verify the
//! found partitions cross-dominate), and checks the paper's bounds
//!   gap ≤ 2‖E‖_*          (any PSD approximation, Eq. 9)
//!   gap ≤ tr(E)           (best rank-r approximation, Eq. 10).

use rkc::clustering::{kernel_kmeans, kernel_kmeans_objective, kmeans, KmeansOpts};
use rkc::data;
use rkc::kernels::{full_kernel_matrix, Kernel};
use rkc::lowrank::{exact_topr_dense, trace_norm_error_psd};
use rkc::metrics::Table;
use rkc::rng::Pcg64;

fn main() -> rkc::error::Result<()> {
    let mut table = Table::new(
        "Theorem 1: L(Ĉ) − L(C*) vs its bounds",
        &["case", "gap", "tr(E)", "2||E||*", "gap≤tr(E)", "gap≤2||E||*"],
    );
    let mut rng = Pcg64::seed(7);
    let mut all_hold = true;

    let cases: Vec<(String, data::Dataset, Kernel, usize)> = vec![
        (
            "blobs n=80 poly2 r=1".into(),
            data::gaussian_blobs(&mut rng, 80, 3, 3, 0.8),
            Kernel::paper_poly2(),
            1,
        ),
        (
            "blobs n=100 poly2 r=2".into(),
            data::gaussian_blobs(&mut rng, 100, 3, 3, 0.7),
            Kernel::paper_poly2(),
            2,
        ),
        (
            "cross_lines n=120 poly2 r=2".into(),
            data::cross_lines(&mut rng, 120),
            Kernel::paper_poly2(),
            2,
        ),
        (
            "moons n=90 rbf r=3".into(),
            data::two_moons(&mut rng, 90, 0.06),
            Kernel::Rbf { gamma: 2.0 },
            3,
        ),
        (
            "segmentation-like n=140 poly2 r=2".into(),
            data::segmentation_like(&mut rng, 140, 19, 7),
            Kernel::paper_poly2(),
            2,
        ),
    ];

    for (name, ds, kernel, r) in cases {
        let k = ds.k;
        let kmat = full_kernel_matrix(&ds.x, kernel);
        let emb = exact_topr_dense(&kmat, r); // best rank-r: E is PSD

        // Ĉ: optimize under K̂ (== standard K-means on Y), score under K
        let opts = KmeansOpts { k, restarts: 80, max_iters: 200, tol: 1e-12 };
        let mut rng_a = Pcg64::seed(11);
        let chat = kmeans(&emb.y, &opts, &mut rng_a);
        let l_chat = kernel_kmeans_objective(&kmat, &chat.labels, k);

        // C*: optimize under the true K
        let mut rng_b = Pcg64::seed(13);
        let cstar = kernel_kmeans(&kmat, k, 80, 300, &mut rng_b);
        // take the better of the two candidates as the believed optimum
        // (kmeans-on-Y solutions are valid partitions for K too)
        let l_cstar = cstar.objective.min(l_chat);

        let gap = (l_chat - l_cstar).max(0.0);
        let tr_e = (kmat.trace() - emb.y.frobenius_norm().powi(2)).max(0.0);
        let tn2 = 2.0 * trace_norm_error_psd(&kmat, &emb);
        let ok1 = gap <= tr_e + 1e-6 * kmat.trace();
        let ok2 = gap <= tn2 + 1e-6 * kmat.trace();
        all_hold &= ok1 && ok2;
        table.row(vec![
            name,
            format!("{gap:.4}"),
            format!("{tr_e:.4}"),
            format!("{tn2:.4}"),
            ok1.to_string(),
            ok2.to_string(),
        ]);
    }

    print!("{}", table.render());
    if !all_hold {
        return Err(rkc::error::RkcError::invalid_config("a Theorem-1 bound was violated!"));
    }
    println!("all bounds hold ✓ (tr(E) is the tighter bound for best rank-r, as Eq. 10 states)");
    Ok(())
}
