//! Quickstart: the library-first API in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Cluster the paper's Fig-1 synthetic set (two crossing thick lines —
//!    plain K-means scores ≈ 0.5 on it) with One-Pass Kernel K-means via
//!    the `KernelClusterer` builder: streaming SRHT sketch → rank-2
//!    recovery → standard K-means.
//! 2. Use the fitted model as a *model*: embed and assign held-out points
//!    it never saw (`two_rings`), checking out-of-sample prediction
//!    matches the in-sample accuracy.

use rkc::api::KernelClusterer;
use rkc::clustering::accuracy;
use rkc::config::Method;
use rkc::data;
use rkc::rng::Pcg64;

fn main() -> rkc::error::Result<()> {
    // --- 1. builder → fit → labels on the crossing-lines workload ---
    let train = data::cross_lines(&mut Pcg64::seed(2016), 1000);
    println!("dataset: {}", train.name);

    let clusterer = KernelClusterer::new(2) // k = 2 clusters
        .rank(2) // embedding rank r (paper: 2)
        .oversample(10) // sketch width r' = r + l (paper: 12)
        .seed(7);
    let model = clusterer.fit(&train.x)?;
    let acc_ours = accuracy(model.labels(), &train.labels, 2);

    let plain = KernelClusterer::new(2).method(Method::PlainKmeans).seed(7).fit(&train.x)?;
    let acc_plain = accuracy(plain.labels(), &train.labels, 2);

    println!(
        "one-pass kernel k-means: accuracy {acc_ours:.3}, approx error {:.3}, peak memory {:.2} MiB",
        model.approx_error()?,
        model.metrics().memory.peak_mib(),
    );
    println!("plain k-means:           accuracy {acc_plain:.3}");
    assert!(acc_ours > acc_plain + 0.2);
    println!("the kernel embedding separates what raw K-means cannot ✓");

    // --- 2. out-of-sample prediction on two_rings ---
    let rings = data::two_rings(&mut Pcg64::seed(11), 1000);
    let ring_model = KernelClusterer::new(2).rank(2).oversample(10).seed(13).fit(&rings.x)?;
    let acc_in = accuracy(ring_model.labels(), &rings.labels, 2);

    let held_out = data::two_rings(&mut Pcg64::seed(17), 500);
    let predicted = ring_model.predict(&held_out.x)?;
    let acc_out = accuracy(&predicted, &held_out.labels, 2);

    println!(
        "two_rings: in-sample accuracy {acc_in:.3}, held-out predict accuracy {acc_out:.3}"
    );
    assert!(
        (acc_in - acc_out).abs() < 0.1,
        "out-of-sample prediction should match in-sample accuracy within noise"
    );
    println!("fit → predict round-trip holds out of sample ✓");
    Ok(())
}
