//! Quickstart: cluster non-linearly-separable data in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Draws the paper's Fig-1 synthetic set (two crossing thick lines —
//! plain K-means scores ≈ 0.5 on it), runs One-Pass Kernel K-means
//! (Alg. 1: streaming SRHT sketch → rank-2 recovery → standard K-means),
//! and prints the clustering accuracy plus the memory footprint.

use rkc::config::{ExperimentConfig, Method};
use rkc::coordinator::{build_dataset, run_trials};

fn main() -> anyhow::Result<()> {
    // Table-1 defaults: cross_lines n=4000, homogeneous quadratic kernel,
    // r = 2, oversampling l = 10 — shrunk to keep the quickstart snappy.
    let mut cfg = ExperimentConfig::table1();
    cfg.n = 1000;
    cfg.trials = 5;

    let ds = build_dataset(&cfg)?;
    println!("dataset: {}", ds.name);

    // the paper's method
    cfg.method = Method::OnePass;
    let ours = run_trials(&cfg, &ds, None)?;

    // plain K-means for contrast
    cfg.method = Method::PlainKmeans;
    let plain = run_trials(&cfg, &ds, None)?;

    println!(
        "one-pass kernel k-means: accuracy {:.3} (± {:.3}), approx error {:.3}, peak memory {:.2} MiB",
        ours.accuracy_mean,
        ours.accuracy_std,
        ours.error_mean,
        ours.peak_memory_bytes as f64 / (1024.0 * 1024.0),
    );
    println!("plain k-means:           accuracy {:.3}", plain.accuracy_mean);
    assert!(ours.accuracy_mean > plain.accuracy_mean + 0.2);
    println!("the kernel embedding separates what raw K-means cannot ✓");
    Ok(())
}
